//! Depth-configurable prefetch pipeline for the blocked engine's
//! `b_n → b_k` panel loop — the generalization of the PR-3 two-slot
//! B-panel ring (`gemm/overlap.rs`, now a shim over this module) to a
//! ring of `pipeline_depth` slots that can prefetch **both** the next
//! block's B panel and its A row-block stripe, driven by the persistent
//! worker pool ([`crate::exec::pool`]) instead of a per-call thread.
//!
//! Schedules, in increasing pipeline depth (all bit-identical *per
//! kernel lane* — same pack routines, same `b_n → b_k` consumption
//! order, same shared sweeps; each driver resolves its
//! [`crate::gemm::kernels`] lane exactly once and uses it for **both**
//! the panel interleave it packs — panel geometry follows the lane's
//! micro-tile ([`crate::gemm::kernels::Lane::tile_dims`]) — and the
//! sweeps that consume those panels, so a run can never mix
//! interleaves; prepacked operands are consumed with the lane recorded
//! at prepack time):
//!
//! * **Serial** — pack then sweep on the critical path
//!   (`gemm/blocked.rs` serial drivers).
//! * **Overlap-B** — the next `(j, k)` block's B panel is packed by a
//!   prefetch job while the sweeps consume the current one; A row
//!   blocks are still packed inside the sweep threads (the paper's
//!   Fig. 7 double-buffered B stream).
//! * **Overlap-AB** — the prefetch job additionally packs the next
//!   block's full A row-block stripe (per executed row block, byte-
//!   identical to the sweeps' own `pack_a`), so the consuming sweeps
//!   run kernel-only; this removes the last packing span from the
//!   compute path, the ROADMAP's "next pipeline depth".
//! * **Prepacked-AB** — the serving variant: B panels stream straight
//!   from a [`PrepackedMatrix`] (pack-B is zero everywhere, not just
//!   off the critical path) and the ring prefetches only A row-block
//!   stripes — **one job per k block**, each stripe swept across every
//!   column block before its slot recycles — so registered-weight
//!   requests run kernel-only sweeps end to end
//!   (`gemm_prepacked_ab_core` / `cube_prepacked_ab_core`).
//!   Consumer-side accounting ([`PrefetchStats`]) records the only
//!   A-staging time that can appear on the critical path of this
//!   schedule: inline fallback packs and ring-wait stalls.
//!
//! **Ring discipline.** `depth` slot buffers circulate between a single
//! prefetch job (claimed from the pool injector via
//! [`crate::exec::pool::Pool::submit`]) and the consuming caller. Jobs
//! are claimed strictly in consumption order; the free-slot supply
//! bounds the lookahead to `depth − 1` blocks past the one being
//! consumed (depth 2 ≡ the PR-3 double buffer; depth 1 degenerates to
//! the serial pack-then-sweep loop). The consumer never waits on work
//! the pool has not started: if the next job is still unclaimed (the
//! prefetch task is queued behind other pool work, or never ran), the
//! consumer claims and packs it **inline** — graceful degradation to
//! the serial schedule instead of a stall, which also makes the ring
//! deadlock-free under full pool saturation.
//!
//! **Scoped-borrow safety.** The prefetch job reaches the operands
//! through a lifetime-erased pointer (`RawPackFn`). Two facts keep it
//! sound: (1) packs only happen for claimed job indices, every claimed
//! job is delivered to and awaited by the consumer before the driver
//! returns; (2) the driver's drop guard (`PrefetchGuard`) sets the
//! ring's shutdown flag and then [`TaskHandle::cancel_or_join`]s the
//! prefetch task — removing it unrun from the queue, or waiting out its
//! current (bounded) step — before the borrowed operands can go out of
//! scope, including on unwind.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Instant;

use crate::exec::pool::{self, TaskHandle};
use crate::gemm::blocked::{
    exec_bm, host_block, sweep_rows_cube, sweep_rows_cube_packed, sweep_rows_f32,
    sweep_rows_f32_packed, sweep_rows_family, sweep_rows_family_packed,
};
use crate::gemm::kernels;
use crate::gemm::pack;
use crate::gemm::prepacked::PrepackedMatrix;
use crate::softfloat::family::SplitSpec;
use crate::util::mat::Matrix;
use crate::util::threads::SendPtr;

/// Default ring depth: two slots — the classic double buffer, one block
/// prefetched ahead of the one being consumed (the PR-3 schedule).
pub const DEFAULT_PIPELINE_DEPTH: usize = 2;

/// Upper bound on the ring depth; beyond a few slots the prefetcher is
/// purely buffer-bound and extra depth only costs panel memory.
pub const MAX_PIPELINE_DEPTH: usize = 8;

/// Clamp a configured depth into the supported `[1, MAX]` window.
pub fn clamp_depth(depth: usize) -> usize {
    depth.clamp(1, MAX_PIPELINE_DEPTH)
}

/// One `(column block, k block)` iteration of the `b_n → b_k` panel
/// loop, in consumption order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PanelJob {
    /// Column-block index (`j0 / b_n`).
    pub jb: usize,
    /// k-block index (`p0 / b_k`).
    pub pb: usize,
    /// First column of the block.
    pub j0: usize,
    /// Columns in the block (`≤ b_n`).
    pub nc: usize,
    /// First k step of the block.
    pub p0: usize,
    /// k steps in the block (`≤ b_k`).
    pub kc: usize,
}

/// The `b_n → b_k` block schedule of the serial drivers, as a flat job
/// list (outer loop over columns, inner over k — the exact consumption
/// order the serial, overlapped-B and overlapped-AB nests all use).
pub fn panel_jobs(n: usize, k: usize, bn: usize, bk: usize) -> Vec<PanelJob> {
    let mut jobs = Vec::new();
    if n == 0 || k == 0 {
        return jobs;
    }
    for (jb, j0) in (0..n).step_by(bn).enumerate() {
        let nc = bn.min(n - j0);
        for (pb, p0) in (0..k).step_by(bk).enumerate() {
            let kc = bk.min(k - p0);
            jobs.push(PanelJob { jb, pb, j0, nc, p0, kc });
        }
    }
    jobs
}

/// What the prefetcher packs B panels from: the plain B matrix
/// (single-component panels) or the split high/low pair (dual-component
/// panels for the fused cube kernel).
pub(crate) enum PanelSource<'a> {
    Single(&'a Matrix<f32>),
    Dual { high: &'a Matrix<f32>, low: &'a Matrix<f32> },
}

impl PanelSource<'_> {
    /// Pack `job`'s B block into `out` with the panel width `nr` of the
    /// consuming lane — exactly what the serial drivers call, so
    /// prefetched panels are byte-identical.
    pub(crate) fn pack(&self, job: &PanelJob, nr: usize, out: &mut Vec<f32>) {
        match self {
            PanelSource::Single(b) => pack::pack_b(b, job.p0, job.kc, job.j0, job.nc, nr, out),
            PanelSource::Dual { high, low } => {
                pack::pack_b_dual(high, low, job.p0, job.kc, job.j0, job.nc, nr, out)
            }
        }
    }
}

/// One ring slot: the packed B panel for a `(j, k)` block, plus — on the
/// A+B schedule — the packed A row-block stripe for the same k block.
#[derive(Default)]
pub struct PanelSlot {
    /// Packed B panel (`pack_b` / `pack_b_dual` output bytes).
    pub b: Vec<f32>,
    /// Concatenated per-row-block A panels (`pack_a` / `pack_a_dual`
    /// output bytes, one segment per executed row block). Empty on the
    /// B-only schedule.
    pub a: Vec<f32>,
    /// `a_off[rb] .. a_off[rb + 1]` bounds row block `rb` inside `a`.
    pub a_off: Vec<usize>,
    /// Reused scratch for the per-row-block A pack (the pack routines
    /// clear their output, so blocks are packed here, then appended).
    scratch: Vec<f32>,
}

/// Pack the full A row-block stripe of one k block, segment per
/// executed row block — byte-identical per segment to the `pack_a` the
/// serial sweeps perform themselves (`mr` is the consuming lane's panel
/// height).
fn pack_a_stripe(a: &Matrix<f32>, bm: usize, p0: usize, kc: usize, mr: usize, slot: &mut PanelSlot) {
    let m = a.rows();
    slot.a.clear();
    slot.a_off.clear();
    slot.a_off.push(0);
    let mut scratch = std::mem::take(&mut slot.scratch);
    for i0 in (0..m).step_by(bm) {
        let mc = bm.min(m - i0);
        pack::pack_a(a, i0, mc, p0, kc, mr, &mut scratch);
        slot.a.extend_from_slice(&scratch);
        slot.a_off.push(slot.a.len());
    }
    slot.scratch = scratch;
}

/// Dual-component counterpart of [`pack_a_stripe`] (`pack_a_dual` per
/// row block).
#[allow(clippy::too_many_arguments)]
fn pack_a_stripe_dual(
    ah: &Matrix<f32>,
    al: &Matrix<f32>,
    bm: usize,
    p0: usize,
    kc: usize,
    mr: usize,
    slot: &mut PanelSlot,
) {
    let m = ah.rows();
    slot.a.clear();
    slot.a_off.clear();
    slot.a_off.push(0);
    let mut scratch = std::mem::take(&mut slot.scratch);
    for i0 in (0..m).step_by(bm) {
        let mc = bm.min(m - i0);
        pack::pack_a_dual(ah, al, i0, mc, p0, kc, mr, &mut scratch);
        slot.a.extend_from_slice(&scratch);
        slot.a_off.push(slot.a.len());
    }
    slot.scratch = scratch;
}

/// Multi-component counterpart of [`pack_a_stripe`]
/// (`pack_a_multi` per row block).
fn pack_a_stripe_multi(
    a_comps: &[Matrix<f32>],
    bm: usize,
    p0: usize,
    kc: usize,
    mr: usize,
    slot: &mut PanelSlot,
) {
    let m = a_comps[0].rows();
    slot.a.clear();
    slot.a_off.clear();
    slot.a_off.push(0);
    let mut scratch = std::mem::take(&mut slot.scratch);
    for i0 in (0..m).step_by(bm) {
        let mc = bm.min(m - i0);
        pack::pack_a_multi(a_comps, i0, mc, p0, kc, mr, &mut scratch);
        slot.a.extend_from_slice(&scratch);
        slot.a_off.push(slot.a.len());
    }
    slot.scratch = scratch;
}

struct RingState {
    n_jobs: usize,
    /// Next job index to claim (claims are strictly in job order).
    next_claim: usize,
    /// Packed slots awaiting consumption (at most `depth − 1` entries).
    ready: Vec<(usize, PanelSlot)>,
    /// Idle slot buffers.
    free: Vec<PanelSlot>,
    /// Consumer is done (or unwinding); the prefetcher must exit.
    shutdown: bool,
    /// The prefetcher panicked mid-pack; the consumer must not wait.
    poisoned: bool,
}

struct Ring {
    state: Mutex<RingState>,
    cv: Condvar,
}

impl Ring {
    /// Poison-tolerant lock: ring invariants are maintained under the
    /// lock only, and both sides must keep draining during unwinds.
    fn lock(&self) -> MutexGuard<'_, RingState> {
        self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    fn wait<'a>(&self, g: MutexGuard<'a, RingState>) -> MutexGuard<'a, RingState> {
        self.cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner)
    }
}

/// Lifetime-erased `&P` of the pack closure, shipped into the detached
/// prefetch task. Sound because packs only run for claimed jobs and the
/// driver cancel-or-joins the task before its borrows end (module docs).
struct RawPackFn {
    data: *const (),
    call: unsafe fn(*const (), usize, &mut PanelSlot),
}
unsafe impl Send for RawPackFn {}

unsafe fn pack_thunk<P: Fn(usize, &mut PanelSlot)>(
    data: *const (),
    idx: usize,
    slot: &mut PanelSlot,
) {
    (*(data as *const P))(idx, slot)
}

/// Body of the detached prefetch task: claim jobs in order whenever a
/// free slot exists, pack off-thread, deliver to the ready list.
fn prefetch_loop(ring: &Ring, raw: RawPackFn) {
    loop {
        let (idx, mut slot) = {
            let mut st = ring.lock();
            loop {
                if st.shutdown || st.poisoned || st.next_claim >= st.n_jobs {
                    return;
                }
                if let Some(slot) = st.free.pop() {
                    let idx = st.next_claim;
                    st.next_claim += 1;
                    break (idx, slot);
                }
                st = ring.wait(st);
            }
        };
        let r = catch_unwind(AssertUnwindSafe(|| unsafe {
            // Failpoint inside the pack step's containment: an armed
            // panic poisons the ring (consumer panics with the typed
            // report), a delay stalls the prefetcher so consumer-wait
            // accounting and serial degeneration get exercised.
            crate::exec::faults::fire("exec.pipeline.prefetch");
            (raw.call)(raw.data, idx, &mut slot)
        }));
        let mut st = ring.lock();
        match r {
            Ok(()) => st.ready.push((idx, slot)),
            Err(_) => st.poisoned = true,
        }
        let poisoned = st.poisoned;
        drop(st);
        ring.cv.notify_all();
        if poisoned {
            return;
        }
    }
}

/// Drop guard of the consuming driver: stops the prefetcher and makes
/// sure its closure can never run again before borrowed operands die —
/// on normal return and on unwind alike.
struct PrefetchGuard<'a> {
    ring: &'a Arc<Ring>,
    handle: Option<TaskHandle>,
}

impl Drop for PrefetchGuard<'_> {
    fn drop(&mut self) {
        self.ring.lock().shutdown = true;
        self.ring.cv.notify_all();
        if let Some(h) = self.handle.take() {
            h.cancel_or_join();
        }
    }
}

/// Consumer-side accounting of one prefetched run: how every job's slot
/// reached the consumer, and how much staging wall time landed on the
/// critical path. `prefetched + inline_packs` always equals the job
/// count; `inline_pack_s + wait_s` is zero exactly when the ring kept
/// up (the kernel-only regime the prepacked serving path targets) —
/// stalls behind a mid-pack prefetcher count as `wait_s`, so a ring
/// that claims jobs but cannot pack them ahead of consumption does not
/// masquerade as kernel-only.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PrefetchStats {
    /// Jobs whose slot was packed ahead of time by the pool prefetch
    /// task — zero pack work on the consumer.
    pub prefetched: usize,
    /// Jobs the consumer packed inline: unclaimed at consumption time
    /// (queued-behind pool, serial degeneration, or depth 1).
    pub inline_packs: usize,
    /// Wall time the consumer spent packing inline.
    pub inline_pack_s: f64,
    /// Wall time the consumer spent blocked on the ring waiting for a
    /// claimed-but-undelivered slot (the prefetcher mid-pack).
    pub wait_s: f64,
}

/// Obtain job `s`'s packed slot: from the ready list if the prefetcher
/// delivered it (`inline: None`), by packing inline if it is still
/// unclaimed (`inline: Some(pack wall time)`), or by waiting iff the
/// prefetcher is actively packing it right now (`waited_s` > 0).
fn acquire_slot<P: Fn(usize, &mut PanelSlot)>(
    ring: &Ring,
    s: usize,
    pack: &P,
) -> (PanelSlot, Option<f64>, f64) {
    let mut waited_s = 0.0f64;
    let mut st = ring.lock();
    loop {
        if st.poisoned {
            drop(st);
            panic!("pipeline prefetch task panicked while packing panels");
        }
        if let Some(pos) = st.ready.iter().position(|(i, _)| *i == s) {
            return (st.ready.swap_remove(pos).1, None, waited_s);
        }
        if st.next_claim == s {
            st.next_claim += 1;
            // Unclaimed job s means every earlier claim was delivered
            // and consumed, so all ring buffers are back on the free
            // list — a free slot must exist.
            let mut slot = st.free.pop().expect("free ring slot for inline pack");
            drop(st);
            let t = Instant::now();
            pack(s, &mut slot);
            return (slot, Some(t.elapsed().as_secs_f64()), waited_s);
        }
        let t = Instant::now();
        st = ring.wait(st);
        waited_s += t.elapsed().as_secs_f64();
    }
}

/// [`run_prefetch_stats`] with the consumer-side accounting discarded —
/// the hot-path entry used by every non-instrumented driver.
pub(crate) fn run_prefetch<P, C>(depth: usize, n_jobs: usize, pack: P, consume: C)
where
    P: Fn(usize, &mut PanelSlot) + Sync,
    C: FnMut(usize, &PanelSlot),
{
    let _ = run_prefetch_stats(depth, n_jobs, pack, consume);
}

/// Run `consume` over every job's packed slot in order, with up to
/// `depth − 1` future jobs packed ahead by a pool prefetch task;
/// returns the consumer-side [`PrefetchStats`].
///
/// `pack(i, slot)` must fill the slot for job `i` deterministically (it
/// runs on the prefetch task *or* inline on the consumer); `consume`
/// always runs on the calling thread, strictly in job order — which is
/// what preserves the serial drivers' per-cell accumulation order and
/// hence bit-identity.
pub(crate) fn run_prefetch_stats<P, C>(
    depth: usize,
    n_jobs: usize,
    pack: P,
    mut consume: C,
) -> PrefetchStats
where
    P: Fn(usize, &mut PanelSlot) + Sync,
    C: FnMut(usize, &PanelSlot),
{
    let mut stats = PrefetchStats::default();
    let depth = clamp_depth(depth);
    let pool = pool::global();
    if pool.n_workers() < 2 || n_jobs < 2 || depth < 2 {
        // Nothing to overlap with (or overlap disabled by depth 1):
        // degenerate to the serial pack-then-consume loop, one reused
        // slot, no detached task — every pack is on the critical path.
        let mut slot = PanelSlot::default();
        for i in 0..n_jobs {
            let t = Instant::now();
            pack(i, &mut slot);
            stats.inline_packs += 1;
            stats.inline_pack_s += t.elapsed().as_secs_f64();
            consume(i, &slot);
        }
        return stats;
    }
    let ring = Arc::new(Ring {
        state: Mutex::new(RingState {
            n_jobs,
            next_claim: 0,
            ready: Vec::new(),
            free: (0..depth.min(n_jobs)).map(|_| PanelSlot::default()).collect(),
            shutdown: false,
            poisoned: false,
        }),
        cv: Condvar::new(),
    });
    let raw = RawPackFn { data: &pack as *const P as *const (), call: pack_thunk::<P> };
    let handle = {
        let ring = Arc::clone(&ring);
        pool.submit(move || prefetch_loop(&ring, raw))
    };
    let _guard = PrefetchGuard { ring: &ring, handle: Some(handle) };
    for s in 0..n_jobs {
        let (slot, inline, waited_s) = acquire_slot(&ring, s, &pack);
        stats.wait_s += waited_s;
        match inline {
            Some(spent) => {
                stats.inline_packs += 1;
                stats.inline_pack_s += spent;
            }
            None => stats.prefetched += 1,
        }
        consume(s, &slot);
        ring.lock().free.push(slot);
        ring.cv.notify_all();
    }
    stats
}

/// Single-component overlapped-B driver — the pipeline counterpart of
/// `blocked::gemm_blocked_core`, bit-identical by shared sweeps (the
/// PR-3 schedule, now pool-backed).
pub(crate) fn gemm_overlapped_core(a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
    gemm_pipeline_single(a, b, false, DEFAULT_PIPELINE_DEPTH)
}

/// Single-component overlapped-AB driver: B panel **and** A row-block
/// stripe of the next block prefetched through a `depth`-slot ring.
pub(crate) fn gemm_ab_core(a: &Matrix<f32>, b: &Matrix<f32>, depth: usize) -> Matrix<f32> {
    gemm_pipeline_single(a, b, true, depth)
}

fn gemm_pipeline_single(a: &Matrix<f32>, b: &Matrix<f32>, ab: bool, depth: usize) -> Matrix<f32> {
    let (m, k) = a.shape();
    let n = b.cols();
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    let block = host_block();
    // One lane per driver call: it fixes the interleave the prefetcher
    // packs *and* the kernels the sweeps dispatch (module docs).
    let lane = kernels::active_lane();
    let (mr, nr) = lane.tile_dims();
    let bm = exec_bm(m, block.bm, mr);
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    let jobs = panel_jobs(n, k, block.bn, block.bk);
    if ab {
        run_prefetch(
            depth,
            jobs.len(),
            |i: usize, slot: &mut PanelSlot| {
                let job = &jobs[i];
                pack::pack_b(b, job.p0, job.kc, job.j0, job.nc, nr, &mut slot.b);
                pack_a_stripe(a, bm, job.p0, job.kc, mr, slot);
            },
            |i: usize, slot: &PanelSlot| {
                let job = &jobs[i];
                sweep_rows_f32_packed(
                    &slot.a, &slot.a_off, m, &slot.b, &cp, n, bm, job.j0, job.kc, lane,
                );
            },
        );
    } else {
        run_prefetch(
            depth,
            jobs.len(),
            |i: usize, slot: &mut PanelSlot| {
                let job = &jobs[i];
                pack::pack_b(b, job.p0, job.kc, job.j0, job.nc, nr, &mut slot.b);
            },
            |i: usize, slot: &PanelSlot| {
                let job = &jobs[i];
                sweep_rows_f32(a, &slot.b, &cp, n, bm, job.j0, job.p0, job.kc, lane);
            },
        );
    }
    c
}

/// Dual-component overlapped-B driver — the pipeline counterpart of
/// `blocked::cube_blocked_core`.
pub(crate) fn cube_overlapped_core(
    ah: &Matrix<f32>,
    al: &Matrix<f32>,
    bh: &Matrix<f32>,
    bl: &Matrix<f32>,
    inv_sf: f32,
) -> Matrix<f32> {
    cube_pipeline_dual(ah, al, bh, bl, inv_sf, false, DEFAULT_PIPELINE_DEPTH)
}

/// Dual-component overlapped-AB driver.
pub(crate) fn cube_ab_core(
    ah: &Matrix<f32>,
    al: &Matrix<f32>,
    bh: &Matrix<f32>,
    bl: &Matrix<f32>,
    inv_sf: f32,
    depth: usize,
) -> Matrix<f32> {
    cube_pipeline_dual(ah, al, bh, bl, inv_sf, true, depth)
}

#[allow(clippy::too_many_arguments)]
fn cube_pipeline_dual(
    ah: &Matrix<f32>,
    al: &Matrix<f32>,
    bh: &Matrix<f32>,
    bl: &Matrix<f32>,
    inv_sf: f32,
    ab: bool,
    depth: usize,
) -> Matrix<f32> {
    let (m, k) = ah.shape();
    let n = bh.cols();
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    let block = host_block();
    let lane = kernels::active_lane();
    let (mr, nr) = lane.tile_dims();
    let bm = exec_bm(m, block.bm, mr);
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    let jobs = panel_jobs(n, k, block.bn, block.bk);
    if ab {
        run_prefetch(
            depth,
            jobs.len(),
            |i: usize, slot: &mut PanelSlot| {
                let job = &jobs[i];
                pack::pack_b_dual(bh, bl, job.p0, job.kc, job.j0, job.nc, nr, &mut slot.b);
                pack_a_stripe_dual(ah, al, bm, job.p0, job.kc, mr, slot);
            },
            |i: usize, slot: &PanelSlot| {
                let job = &jobs[i];
                sweep_rows_cube_packed(
                    &slot.a, &slot.a_off, m, &slot.b, &cp, n, bm, job.j0, job.kc, inv_sf, lane,
                );
            },
        );
    } else {
        run_prefetch(
            depth,
            jobs.len(),
            |i: usize, slot: &mut PanelSlot| {
                let job = &jobs[i];
                pack::pack_b_dual(bh, bl, job.p0, job.kc, job.j0, job.nc, nr, &mut slot.b);
            },
            |i: usize, slot: &PanelSlot| {
                let job = &jobs[i];
                sweep_rows_cube(ah, al, &slot.b, &cp, n, bm, job.j0, job.p0, job.kc, inv_sf, lane);
            },
        );
    }
    c
}

/// Multi-component overlapped-B driver — the pipeline counterpart of
/// `blocked::family_blocked_core` (N-term family tiers).
pub(crate) fn family_overlapped_core(
    a_comps: &[Matrix<f32>],
    b_comps: &[Matrix<f32>],
    spec: &SplitSpec,
) -> Matrix<f32> {
    family_pipeline_multi(a_comps, b_comps, spec, false, DEFAULT_PIPELINE_DEPTH)
}

/// Multi-component overlapped-AB driver.
pub(crate) fn family_ab_core(
    a_comps: &[Matrix<f32>],
    b_comps: &[Matrix<f32>],
    spec: &SplitSpec,
    depth: usize,
) -> Matrix<f32> {
    family_pipeline_multi(a_comps, b_comps, spec, true, depth)
}

fn family_pipeline_multi(
    a_comps: &[Matrix<f32>],
    b_comps: &[Matrix<f32>],
    spec: &SplitSpec,
    ab: bool,
    depth: usize,
) -> Matrix<f32> {
    let (m, k) = a_comps[0].shape();
    let n = b_comps[0].cols();
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    let block = host_block();
    let lane = kernels::active_lane();
    let (mr, nr) = lane.tile_dims();
    let bm = exec_bm(m, block.bm, mr);
    let weights = spec.order_weights();
    let ncomp = spec.ncomp();
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    let jobs = panel_jobs(n, k, block.bn, block.bk);
    if ab {
        run_prefetch(
            depth,
            jobs.len(),
            |i: usize, slot: &mut PanelSlot| {
                let job = &jobs[i];
                pack::pack_b_multi(b_comps, job.p0, job.kc, job.j0, job.nc, nr, &mut slot.b);
                pack_a_stripe_multi(a_comps, bm, job.p0, job.kc, mr, slot);
            },
            |i: usize, slot: &PanelSlot| {
                let job = &jobs[i];
                sweep_rows_family_packed(
                    &slot.a, &slot.a_off, m, &slot.b, &cp, n, bm, job.j0, job.kc, &weights,
                    ncomp, lane,
                );
            },
        );
    } else {
        run_prefetch(
            depth,
            jobs.len(),
            |i: usize, slot: &mut PanelSlot| {
                let job = &jobs[i];
                pack::pack_b_multi(b_comps, job.p0, job.kc, job.j0, job.nc, nr, &mut slot.b);
            },
            |i: usize, slot: &PanelSlot| {
                let job = &jobs[i];
                sweep_rows_family(
                    a_comps, &slot.b, &cp, n, bm, job.j0, job.p0, job.kc, &weights, ncomp, lane,
                );
            },
        );
    }
    c
}

/// Single-component prepacked-B pipeline driver: B panels stream
/// straight from the [`PrepackedMatrix`] (no pack-B work exists at
/// all) while the ring prefetches only A row-block stripes — the
/// consuming packed sweeps run kernel-only.
///
/// **Nest order.** The stripe for k block `pb` depends only on
/// `(p0, kc)`, so the ring runs **one job per k block** and the
/// consumer sweeps that stripe across *every* column block before
/// releasing the slot (k-outer / column-inner) — each stripe is packed
/// exactly once, instead of once per column block as the jb-outer
/// serial nest does. Still **bit-identical** to
/// `blocked::gemm_prepacked`: every output cell receives its k-block
/// contributions in ascending `pb` order either way (cells in
/// different column blocks never share an accumulation chain), the
/// `pack_a` segments are byte-identical, and the per-block sweeps are
/// the same shared code.
pub(crate) fn gemm_prepacked_ab_core(
    a: &Matrix<f32>,
    b: &PrepackedMatrix,
    depth: usize,
) -> Matrix<f32> {
    gemm_prepacked_ab_with_stats(a, b, depth).0
}

/// [`gemm_prepacked_ab_core`] returning the consumer-side
/// [`PrefetchStats`] (the instrumented serving path).
pub(crate) fn gemm_prepacked_ab_with_stats(
    a: &Matrix<f32>,
    b: &PrepackedMatrix,
    depth: usize,
) -> (Matrix<f32>, PrefetchStats) {
    let (m, k) = a.shape();
    let n = b.n();
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return (c, PrefetchStats::default());
    }
    // Panels in `b` were interleaved for the lane recorded at prepack
    // time; the A stripes and sweeps must use the same lane.
    let lane = b.lane();
    let (mr, _) = lane.tile_dims();
    let bm = exec_bm(m, host_block().bm, mr);
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    let (bk, bn) = (b.bk(), b.bn());
    let stats = run_prefetch_stats(
        depth,
        b.k_blocks(),
        |pb: usize, slot: &mut PanelSlot| {
            let p0 = pb * bk;
            pack_a_stripe(a, bm, p0, bk.min(k - p0), mr, slot);
        },
        |pb: usize, slot: &PanelSlot| {
            let p0 = pb * bk;
            let kc = bk.min(k - p0);
            for (jb, j0) in (0..n).step_by(bn).enumerate() {
                sweep_rows_f32_packed(
                    &slot.a,
                    &slot.a_off,
                    m,
                    b.panel(jb, pb),
                    &cp,
                    n,
                    bm,
                    j0,
                    kc,
                    lane,
                );
            }
        },
    );
    (c, stats)
}

/// Dual-component prepacked-B pipeline driver (cube counterpart of
/// [`gemm_prepacked_ab_core`], same one-job-per-k-block nest): cached
/// dual-format B panels, each dual A stripe prefetched once, kernel-only
/// fused sweeps.
pub(crate) fn cube_prepacked_ab_core(
    ah: &Matrix<f32>,
    al: &Matrix<f32>,
    b: &PrepackedMatrix,
    inv_sf: f32,
    depth: usize,
) -> Matrix<f32> {
    cube_prepacked_ab_with_stats(ah, al, b, inv_sf, depth).0
}

/// [`cube_prepacked_ab_core`] returning the consumer-side
/// [`PrefetchStats`].
pub(crate) fn cube_prepacked_ab_with_stats(
    ah: &Matrix<f32>,
    al: &Matrix<f32>,
    b: &PrepackedMatrix,
    inv_sf: f32,
    depth: usize,
) -> (Matrix<f32>, PrefetchStats) {
    let (m, k) = ah.shape();
    let n = b.n();
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return (c, PrefetchStats::default());
    }
    let lane = b.lane();
    let (mr, _) = lane.tile_dims();
    let bm = exec_bm(m, host_block().bm, mr);
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    let (bk, bn) = (b.bk(), b.bn());
    let stats = run_prefetch_stats(
        depth,
        b.k_blocks(),
        |pb: usize, slot: &mut PanelSlot| {
            let p0 = pb * bk;
            pack_a_stripe_dual(ah, al, bm, p0, bk.min(k - p0), mr, slot);
        },
        |pb: usize, slot: &PanelSlot| {
            let p0 = pb * bk;
            let kc = bk.min(k - p0);
            for (jb, j0) in (0..n).step_by(bn).enumerate() {
                sweep_rows_cube_packed(
                    &slot.a, &slot.a_off, m, b.panel(jb, pb), &cp, n, bm, j0, kc, inv_sf, lane,
                );
            }
        },
    );
    (c, stats)
}

/// Multi-component prepacked-B pipeline driver (family counterpart of
/// [`cube_prepacked_ab_core`], same one-job-per-k-block nest): cached
/// multi-format B panels, each multi-component A stripe prefetched
/// once, kernel-only N-term sweeps.
pub(crate) fn family_prepacked_ab_core(
    a_comps: &[Matrix<f32>],
    b: &PrepackedMatrix,
    spec: &SplitSpec,
    depth: usize,
) -> Matrix<f32> {
    family_prepacked_ab_with_stats(a_comps, b, spec, depth).0
}

/// [`family_prepacked_ab_core`] returning the consumer-side
/// [`PrefetchStats`].
pub(crate) fn family_prepacked_ab_with_stats(
    a_comps: &[Matrix<f32>],
    b: &PrepackedMatrix,
    spec: &SplitSpec,
    depth: usize,
) -> (Matrix<f32>, PrefetchStats) {
    let (m, k) = a_comps[0].shape();
    let n = b.n();
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return (c, PrefetchStats::default());
    }
    let lane = b.lane();
    let (mr, _) = lane.tile_dims();
    let bm = exec_bm(m, host_block().bm, mr);
    let weights = spec.order_weights();
    let ncomp = spec.ncomp();
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    let (bk, bn) = (b.bk(), b.bn());
    let stats = run_prefetch_stats(
        depth,
        b.k_blocks(),
        |pb: usize, slot: &mut PanelSlot| {
            let p0 = pb * bk;
            pack_a_stripe_multi(a_comps, bm, p0, bk.min(k - p0), mr, slot);
        },
        |pb: usize, slot: &PanelSlot| {
            let p0 = pb * bk;
            let kc = bk.min(k - p0);
            for (jb, j0) in (0..n).step_by(bn).enumerate() {
                sweep_rows_family_packed(
                    &slot.a, &slot.a_off, m, b.panel(jb, pb), &cp, n, bm, j0, kc, &weights,
                    ncomp, lane,
                );
            }
        },
    );
    (c, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn clamp_depth_window() {
        assert_eq!(clamp_depth(0), 1);
        assert_eq!(clamp_depth(1), 1);
        assert_eq!(clamp_depth(2), 2);
        assert_eq!(clamp_depth(100), MAX_PIPELINE_DEPTH);
    }

    #[test]
    fn panel_jobs_cover_the_nest_in_order() {
        let jobs = panel_jobs(70, 130, 32, 64);
        // n=70/bn=32 → j0 in {0,32,64}; k=130/bk=64 → p0 in {0,64,128}.
        assert_eq!(jobs.len(), 9);
        assert_eq!(jobs[0], PanelJob { jb: 0, pb: 0, j0: 0, nc: 32, p0: 0, kc: 64 });
        assert_eq!(jobs[2], PanelJob { jb: 0, pb: 2, j0: 0, nc: 32, p0: 128, kc: 2 });
        assert_eq!(jobs[8], PanelJob { jb: 2, pb: 2, j0: 64, nc: 6, p0: 128, kc: 2 });
        for w in jobs.windows(2) {
            assert!((w[0].jb, w[0].pb) < (w[1].jb, w[1].pb));
        }
        assert!(panel_jobs(0, 64, 32, 32).is_empty());
        assert!(panel_jobs(64, 0, 32, 32).is_empty());
    }

    #[test]
    fn run_prefetch_delivers_every_job_in_order_at_every_depth() {
        for depth in [1usize, 2, 3, 4] {
            let mut seen = Vec::new();
            run_prefetch(
                depth,
                9,
                |i: usize, slot: &mut PanelSlot| {
                    slot.b.clear();
                    slot.b.push(i as f32);
                },
                |i: usize, slot: &PanelSlot| {
                    assert_eq!(slot.b, vec![i as f32], "depth {depth}");
                    seen.push(i);
                },
            );
            assert_eq!(seen, (0..9).collect::<Vec<_>>(), "depth {depth}");
        }
        // Empty and single-job rings.
        let mut count = 0;
        run_prefetch(2, 0, |_: usize, _: &mut PanelSlot| {}, |_: usize, _: &PanelSlot| count += 1);
        assert_eq!(count, 0);
        run_prefetch(3, 1, |_: usize, _: &mut PanelSlot| {}, |_: usize, _: &PanelSlot| count += 1);
        assert_eq!(count, 1);
    }

    #[test]
    fn prefetched_slots_byte_match_serial_packs() {
        use crate::gemm::pack::{MAX_MR, MAX_NR, MR, NR};
        let mut rng = Rng::new(91);
        let a = Matrix::random_symmetric(37, 100, 0, &mut rng);
        let b = Matrix::random_symmetric(100, 50, 0, &mut rng);
        let jobs = panel_jobs(50, 100, 16, 32);
        // Both the narrow and the wide lane geometries stage
        // byte-identically.
        for (mr, nr, bm) in [(MR, NR, 8), (MAX_MR, MAX_NR, 16)] {
            // Serial reference: pack_b plus the per-row-block pack_a
            // stripe.
            let mut want = Vec::new();
            for job in &jobs {
                let mut bp = Vec::new();
                pack::pack_b(&b, job.p0, job.kc, job.j0, job.nc, nr, &mut bp);
                let mut ap = Vec::new();
                let mut tmp = Vec::new();
                for i0 in (0..a.rows()).step_by(bm) {
                    let mc = bm.min(a.rows() - i0);
                    pack::pack_a(&a, i0, mc, job.p0, job.kc, mr, &mut tmp);
                    ap.extend_from_slice(&tmp);
                }
                want.push((bp, ap));
            }
            let mut got: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
            run_prefetch(
                3,
                jobs.len(),
                |i: usize, slot: &mut PanelSlot| {
                    let job = &jobs[i];
                    pack::pack_b(&b, job.p0, job.kc, job.j0, job.nc, nr, &mut slot.b);
                    pack_a_stripe(&a, bm, job.p0, job.kc, mr, slot);
                },
                |_: usize, slot: &PanelSlot| got.push((slot.b.clone(), slot.a.clone())),
            );
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.0, w.0, "mr={mr} prefetched B panel differs from serial pack");
                assert_eq!(g.1, w.1, "mr={mr} prefetched A stripe differs from serial packs");
            }
        }
    }

    #[test]
    fn prefetch_stats_account_every_job_exactly_once() {
        for depth in [1usize, 2, 3] {
            let stats = run_prefetch_stats(
                depth,
                7,
                |i: usize, slot: &mut PanelSlot| {
                    slot.b.clear();
                    slot.b.push(i as f32);
                },
                |i: usize, slot: &PanelSlot| assert_eq!(slot.b, vec![i as f32], "depth {depth}"),
            );
            assert_eq!(stats.prefetched + stats.inline_packs, 7, "depth {depth}");
            if depth < 2 || pool::global().n_workers() < 2 {
                // Serial degeneration: every pack is on the critical
                // path and the consumer never blocks on the ring.
                assert_eq!(stats.prefetched, 0, "depth {depth}");
                assert_eq!(stats.inline_packs, 7, "depth {depth}");
                assert_eq!(stats.wait_s, 0.0, "depth {depth}");
            }
            assert!(stats.inline_pack_s >= 0.0);
            assert!(stats.wait_s >= 0.0);
            if stats.inline_packs == 0 {
                assert_eq!(stats.inline_pack_s, 0.0);
            }
        }
        // Empty runs account nothing.
        let noop_pack = |_: usize, _: &mut PanelSlot| {};
        let stats = run_prefetch_stats(2, 0, noop_pack, |_: usize, _: &PanelSlot| {});
        assert_eq!(stats, PrefetchStats::default());
    }

    #[test]
    fn prepacked_ab_stripes_match_serial_consumption_geometry() {
        // The prepacked driver must walk the exact (jb, pb) grid the
        // serial prepacked nest walks and feed byte-identical A stripes;
        // full bit-identity of the results is pinned at the blocked
        // entry points and in tests/properties.rs.
        let mut rng = Rng::new(93);
        let a = Matrix::random_symmetric(13, 70, 0, &mut rng);
        let b = Matrix::random_symmetric(70, 37, 0, &mut rng);
        let pp = PrepackedMatrix::prepack(&b, crate::gemm::prepacked::PrepackPath::Fp32);
        let (c, stats) = gemm_prepacked_ab_with_stats(&a, &pp, 3);
        let want = crate::gemm::blocked::gemm_prepacked(&a, &pp);
        for (x, y) in c.as_slice().iter().zip(want.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // One ring job per k block — each stripe packed exactly once.
        assert_eq!(stats.prefetched + stats.inline_packs, pp.k_blocks());
    }

    #[test]
    fn pack_a_stripe_offsets_bound_row_blocks() {
        use crate::gemm::pack::MR;
        let mut rng = Rng::new(92);
        let a = Matrix::random_symmetric(21, 16, 0, &mut rng);
        let mut slot = PanelSlot::default();
        pack_a_stripe(&a, 8, 0, 16, MR, &mut slot);
        // 21 rows / bm=8 → 3 row blocks (8, 8, 5 rows).
        assert_eq!(slot.a_off.len(), 4);
        assert_eq!(slot.a_off[0], 0);
        assert_eq!(*slot.a_off.last().unwrap(), slot.a.len());
        let mut tmp = Vec::new();
        pack::pack_a(&a, 16, 5, 0, 16, MR, &mut tmp);
        assert_eq!(&slot.a[slot.a_off[2]..slot.a_off[3]], &tmp[..]);
    }

    #[test]
    fn pack_panic_propagates_to_the_consumer() {
        let r = catch_unwind(AssertUnwindSafe(|| {
            run_prefetch(
                2,
                4,
                |i: usize, _: &mut PanelSlot| {
                    if i == 2 {
                        panic!("pack blew up");
                    }
                },
                |_: usize, _: &PanelSlot| {},
            );
        }));
        // Whether job 2 was packed inline (original payload) or by the
        // prefetch task (ring-poisoned report), the consumer panics.
        assert!(r.is_err(), "pack panic must reach the consumer");
    }
}
