//! Persistent worker pool — one fixed thread population for blocked
//! sweeps, pipeline prefetch and the serving tier.
//!
//! Before this subsystem the repo ran three uncoordinated thread
//! populations: a `std::thread::scope` spawn/join round per `(j, k)`
//! block sweep (`util::threads::parallel_chunks`), a fresh prefetch
//! thread per overlapped GEMM call (`gemm::overlap`), and a resident
//! worker set per `GemmService`. Under concurrent serving load those
//! multiply into `cores × requests` runnable threads. The pool replaces
//! all three with **one** lazily-initialized population of
//! `num_threads()` workers ([`global`]) that lives for the process:
//!
//! * [`Pool::run_chunks`] — the scoped data-parallel primitive with the
//!   exact disjoint-chunk contract of the old `parallel_chunks`
//!   (same chunking, same `Sync` requirements), executed by pool
//!   workers **and the calling thread together**. The caller
//!   participates in draining the chunk batch, so a saturated (or
//!   single-worker) pool can never deadlock a sweep — worst case the
//!   caller runs every chunk itself, which is the old serial
//!   degeneration with zero spawn cost.
//! * [`Pool::submit`] — detached jobs (pipeline prefetch, service
//!   batches) pushed to a shared injector queue, with a [`TaskHandle`]
//!   that can observe, cancel-before-start, or join the job.
//!
//! Queue discipline: one injector ([`Pool::submit`]) plus one queue per
//! worker ([`Pool::run_chunks`] enlists every worker through its own
//! queue). Workers prefer their own queue, so sweep chunks — latency
//! critical, caller blocked — jump ahead of queued detached jobs. All
//! queues hang off a single mutex: tasks are block-granular (a chunk
//! batch, a panel pack, a request batch), so the lock is cold compared
//! to the work it hands out.
//!
//! **Work stealing.** A worker whose own queue and the injector are both
//! empty does not park while a peer's queue is backed up: it steals the
//! front task from the *deepest* non-empty peer queue. This matters
//! under skew — a worker pinned by a long detached job (a gated service
//! batch, a slow prefetch) leaves its enlisted sweep-chunk drains
//! queued, and without stealing those drains would wait for the pinned
//! worker while free workers sleep. Stolen tasks are safe by
//! construction: worker queues only ever hold anonymous
//! `ChunkBatch::drain` participants (chunk claims are atomic, and extra
//! drains of a finished batch no-op), and handle-carrying detached jobs
//! live in the injector, which [`TaskHandle::cancel_or_join`] scans —
//! so cancellation semantics are untouched. Steal traffic is counted
//! ([`Pool::steals`] / [`Pool::steal_fails`]) and surfaced through the
//! coordinator metrics as `exec/steal_ratio`.
//!
//! Panic discipline: a panic inside a `run_chunks` closure is caught on
//! the executing thread, the batch still completes, and the first
//! payload is re-thrown on the **calling** thread (same observable
//! behaviour as the old scoped spawn). A panic inside a detached job is
//! caught and swallowed by the worker — detached submitters own their
//! own failure signalling (the pipeline ring poisons itself, the
//! service replies with a typed error) — and the worker thread
//! survives.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Observable lifecycle of a detached task submitted with
/// [`Pool::submit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskState {
    /// In the injector, not yet picked up by a worker.
    Queued,
    /// A worker is executing it.
    Running,
    /// Finished (including by panic — detached panics are swallowed).
    Done,
    /// Removed from the queue by [`TaskHandle::cancel_or_join`] before
    /// any worker started it; the closure never ran.
    Cancelled,
}

struct StatusCell {
    state: Mutex<TaskState>,
    changed: Condvar,
}

/// Handle to a detached task. Dropping it detaches the task for good.
pub struct TaskHandle {
    cell: Arc<StatusCell>,
    shared: Arc<Shared>,
}

impl TaskHandle {
    /// Current lifecycle state.
    pub fn state(&self) -> TaskState {
        *self.cell.state.lock().unwrap()
    }

    /// Cancel the task if it has not started (it will then never run),
    /// otherwise wait for it to finish. On return the task's closure is
    /// guaranteed to not be running and to never run again — the
    /// property scoped users (the pipeline ring) need before letting
    /// borrowed data go out of scope. Never blocks behind *other*
    /// queued tasks: a still-queued task is removed, not waited for.
    pub fn cancel_or_join(&self) -> TaskState {
        {
            let mut q = self.shared.state.lock().unwrap();
            let before = q.injector.len();
            q.injector.retain(|t| match &t.status {
                Some(c) => !Arc::ptr_eq(c, &self.cell),
                None => true,
            });
            if q.injector.len() < before {
                let mut st = self.cell.state.lock().unwrap();
                *st = TaskState::Cancelled;
                self.cell.changed.notify_all();
                return TaskState::Cancelled;
            }
        }
        self.join()
    }

    /// Block until the task finished (or was cancelled).
    pub fn join(&self) -> TaskState {
        let mut st = self.cell.state.lock().unwrap();
        while !matches!(*st, TaskState::Done | TaskState::Cancelled) {
            st = self.cell.changed.wait(st).unwrap();
        }
        *st
    }
}

struct Task {
    run: Box<dyn FnOnce() + Send + 'static>,
    /// Present for handle-carrying detached jobs; `run_chunks`
    /// participants are anonymous.
    status: Option<Arc<StatusCell>>,
}

struct Queues {
    injector: VecDeque<Task>,
    worker: Vec<VecDeque<Task>>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<Queues>,
    work: Condvar,
    /// Tasks currently executing on pool workers (caller participation
    /// in `run_chunks` is not counted — it spends the caller's thread,
    /// not a pool worker).
    active: AtomicUsize,
    /// High-water mark of `active`; by construction it can never exceed
    /// the worker count — exposed so tests can pin that invariant.
    high_water: AtomicUsize,
    /// Tasks taken from a peer worker's queue (see module docs).
    steals: AtomicU64,
    /// Scans that found the own queue, the injector and every peer
    /// queue empty, immediately before the worker parked.
    steal_fails: AtomicU64,
}

/// A fixed-size persistent worker pool. See the module docs; most code
/// uses the process-wide [`global`] instance via
/// [`crate::util::threads::parallel_chunks`].
pub struct Pool {
    shared: Arc<Shared>,
    n_workers: usize,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl Pool {
    /// Spawn a pool with `n_workers` threads (clamped to at least one).
    pub fn new(n_workers: usize) -> Pool {
        let n = n_workers.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(Queues {
                injector: VecDeque::new(),
                worker: (0..n).map(|_| VecDeque::new()).collect(),
                shutdown: false,
            }),
            work: Condvar::new(),
            active: AtomicUsize::new(0),
            high_water: AtomicUsize::new(0),
            steals: AtomicU64::new(0),
            steal_fails: AtomicU64::new(0),
        });
        let mut handles = Vec::with_capacity(n);
        for w in 0..n {
            let shared = Arc::clone(&shared);
            let h = std::thread::Builder::new()
                .name(format!("sgemm-pool-{w}"))
                .spawn(move || worker_main(&shared, w))
                .expect("spawning pool worker thread");
            handles.push(h);
        }
        Pool { shared, n_workers: n, handles: Mutex::new(handles) }
    }

    /// Number of worker threads (fixed at construction).
    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Tasks currently executing on pool workers.
    pub fn active(&self) -> usize {
        self.shared.active.load(Ordering::SeqCst)
    }

    /// High-water mark of concurrently executing pool-worker tasks;
    /// `high_water() <= n_workers()` always holds.
    pub fn high_water(&self) -> usize {
        self.shared.high_water.load(Ordering::SeqCst)
    }

    /// Tasks a worker took from a peer's queue instead of parking
    /// (cumulative; see the work-stealing section of the module docs).
    pub fn steals(&self) -> u64 {
        self.shared.steals.load(Ordering::Relaxed)
    }

    /// Idle scans that found nothing to run *or* steal, immediately
    /// before the worker parked (cumulative). The steal ratio
    /// `steals / (steals + steal_fails)` is the fig11 `exec/steal_ratio`
    /// record.
    pub fn steal_fails(&self) -> u64 {
        self.shared.steal_fails.load(Ordering::Relaxed)
    }

    /// Submit a detached job to the injector queue. It runs exactly once
    /// on some worker (unless cancelled first via the returned handle).
    pub fn submit(&self, f: impl FnOnce() + Send + 'static) -> TaskHandle {
        let cell = Arc::new(StatusCell {
            state: Mutex::new(TaskState::Queued),
            changed: Condvar::new(),
        });
        // Failpoint at the head of every detached task, before the
        // closure runs or claims anything: a chaos-armed panic here is
        // exactly a worker dying at task start (contained like any
        // detached panic), and a delay models a slow pickup.
        let run = Box::new(move || {
            crate::exec::faults::fire("exec.pool.task");
            f();
        });
        {
            let mut q = self.shared.state.lock().unwrap();
            q.injector.push_back(Task { run, status: Some(Arc::clone(&cell)) });
        }
        self.shared.work.notify_all();
        TaskHandle { cell, shared: Arc::clone(&self.shared) }
    }

    /// Run `f(start, end)` over disjoint chunks of `0..n`, blocking
    /// until every chunk completed — the drop-in contract of the old
    /// scoped `parallel_chunks` (same chunk geometry: up to
    /// `n_workers` contiguous chunks of `ceil(n / workers)`), without
    /// the per-call spawn/join round.
    ///
    /// The calling thread participates in draining the batch, so this
    /// never deadlocks regardless of pool saturation or nesting (a
    /// pool worker may itself call `run_chunks`). `f` must be `Sync`;
    /// disjoint-output safety (e.g. raw-pointer writes per index range)
    /// remains the caller's responsibility, exactly as before.
    ///
    /// A panic inside `f` is re-thrown on the calling thread with its
    /// original payload once the batch has fully completed.
    pub fn run_chunks<F>(&self, n: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        let workers = self.n_workers.min(n.max(1));
        if workers <= 1 || n == 0 {
            f(0, n);
            return;
        }
        let chunk = n.div_ceil(workers);
        let n_chunks = n.div_ceil(chunk);
        let batch = Arc::new(ChunkBatch {
            raw: RawChunkFn { data: &f as *const F as *const (), call: chunk_thunk::<F> },
            n,
            chunk,
            n_chunks,
            next: AtomicUsize::new(0),
            remaining: Mutex::new(n_chunks),
            finished: Condvar::new(),
            panic: Mutex::new(None),
        });
        {
            // One participant per chunk is enough — the caller is an
            // extra executor on top, and with this module's chunk math
            // n_chunks <= n_workers, so large batches still enlist
            // every worker. (Workers not enlisted can't help, but the
            // caller's own drain bounds the worst case.)
            let mut q = self.shared.state.lock().unwrap();
            for wq in q.worker.iter_mut().take(n_chunks) {
                let b = Arc::clone(&batch);
                wq.push_back(Task { run: Box::new(move || b.drain()), status: None });
            }
        }
        self.shared.work.notify_all();
        // The caller participates: claim and run chunks until none are
        // left unclaimed...
        batch.drain();
        // ...then wait out the chunks other workers claimed.
        let mut rem = batch.remaining.lock().unwrap();
        while *rem > 0 {
            rem = batch.finished.wait(rem).unwrap();
        }
        drop(rem);
        if let Some(payload) = batch.panic.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.work.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_main(shared: &Arc<Shared>, me: usize) {
    loop {
        let task = {
            let mut q = shared.state.lock().unwrap();
            loop {
                // Own queue first: sweep chunks (a blocked caller) beat
                // queued detached jobs.
                if let Some(t) = q.worker[me].pop_front() {
                    break t;
                }
                if let Some(t) = q.injector.pop_front() {
                    break t;
                }
                // Nothing of our own: steal from the deepest peer queue
                // rather than sleeping while a pinned worker's backlog
                // waits (only status-None chunk drains ever live here;
                // see the module docs for why that makes stealing safe).
                let victim = (0..q.worker.len())
                    .filter(|&w| w != me && !q.worker[w].is_empty())
                    .max_by_key(|&w| q.worker[w].len());
                if let Some(v) = victim {
                    shared.steals.fetch_add(1, Ordering::Relaxed);
                    break q.worker[v].pop_front().expect("victim queue observed non-empty");
                }
                if q.shutdown {
                    return;
                }
                shared.steal_fails.fetch_add(1, Ordering::Relaxed);
                q = shared.work.wait(q).unwrap();
            }
        };
        if let Some(cell) = &task.status {
            *cell.state.lock().unwrap() = TaskState::Running;
            cell.changed.notify_all();
        }
        let active = shared.active.fetch_add(1, Ordering::SeqCst) + 1;
        shared.high_water.fetch_max(active, Ordering::SeqCst);
        let status = task.status;
        // Detached panics are contained here (the submitter signals its
        // own failures); run_chunks participants contain theirs in
        // ChunkBatch::drain and re-throw on the caller.
        let _ = catch_unwind(AssertUnwindSafe(task.run));
        shared.active.fetch_sub(1, Ordering::SeqCst);
        if let Some(cell) = status {
            *cell.state.lock().unwrap() = TaskState::Done;
            cell.changed.notify_all();
        }
    }
}

/// Lifetime-erased `&F` of a chunk closure. Safety argument: the only
/// dereference site is [`ChunkBatch::drain`], gated on claiming a chunk
/// index below `n_chunks` — and the submitting caller stays blocked in
/// [`Pool::run_chunks`] until every claimed chunk has finished, so the
/// referent outlives every dereference. Stale participant tasks popped
/// after the batch completed observe `next >= n_chunks` and never touch
/// the pointer.
struct RawChunkFn {
    data: *const (),
    call: unsafe fn(*const (), usize, usize),
}
unsafe impl Send for RawChunkFn {}
unsafe impl Sync for RawChunkFn {}

unsafe fn chunk_thunk<F: Fn(usize, usize)>(data: *const (), start: usize, end: usize) {
    (*(data as *const F))(start, end)
}

struct ChunkBatch {
    raw: RawChunkFn,
    n: usize,
    chunk: usize,
    n_chunks: usize,
    next: AtomicUsize,
    remaining: Mutex<usize>,
    finished: Condvar,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl ChunkBatch {
    /// Claim and run chunks until none remain unclaimed. Runs on pool
    /// workers and on the submitting caller alike.
    fn drain(&self) {
        loop {
            let idx = self.next.fetch_add(1, Ordering::SeqCst);
            if idx >= self.n_chunks {
                return;
            }
            let start = idx * self.chunk;
            let end = ((idx + 1) * self.chunk).min(self.n);
            // SAFETY: idx < n_chunks, so the submitting caller is still
            // blocked in run_chunks and the erased closure is alive
            // (see RawChunkFn).
            let r = catch_unwind(AssertUnwindSafe(|| unsafe {
                (self.raw.call)(self.raw.data, start, end)
            }));
            if let Err(payload) = r {
                let mut slot = self.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            let mut rem = self.remaining.lock().unwrap();
            *rem -= 1;
            if *rem == 0 {
                self.finished.notify_all();
            }
        }
    }
}

/// The process-wide pool, created on first use and sized **once** from
/// [`crate::util::threads::num_threads`] (`SGEMM_CUBE_THREADS` override,
/// else available parallelism). Every blocked sweep, pipeline prefetch
/// and (by default) serving batch runs here.
pub fn global() -> &'static Pool {
    static GLOBAL: OnceLock<Pool> = OnceLock::new();
    GLOBAL.get_or_init(|| Pool::new(crate::util::threads::num_threads()))
}

/// Spawn a dedicated named **control** thread (service dispatchers and
/// similar long-lived loops that mostly block on channels). Control
/// threads must not run on pool workers — parking a worker on a channel
/// for the process lifetime would permanently shrink the compute pool —
/// so this is the sanctioned escape hatch that keeps direct
/// `std::thread::spawn` calls out of the serving and engine layers.
pub fn spawn_named<F>(name: &str, f: F) -> JoinHandle<()>
where
    F: FnOnce() + Send + 'static,
{
    std::thread::Builder::new()
        .name(name.to_string())
        .spawn(f)
        .expect("spawning control thread")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::mpsc::channel;

    #[test]
    fn run_chunks_covers_all_indices_once() {
        let pool = Pool::new(4);
        let counter = AtomicUsize::new(0);
        pool.run_chunks(1000, |s, e| {
            counter.fetch_add(e - s, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
        // Zero and one-element ranges take the serial path.
        pool.run_chunks(0, |s, e| assert_eq!((s, e), (0, 0)));
        let hit = AtomicUsize::new(0);
        pool.run_chunks(1, |s, e| {
            assert_eq!((s, e), (0, 1));
            hit.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn run_chunks_nests_without_deadlock() {
        // A chunk closure that itself fans out on the same pool: the
        // caller-participation design must keep making progress even
        // when every worker is already busy with the outer batch.
        let pool = Pool::new(2);
        let counter = AtomicUsize::new(0);
        pool.run_chunks(4, |s, e| {
            for _ in s..e {
                pool.run_chunks(8, |s2, e2| {
                    counter.fetch_add(e2 - s2, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn run_chunks_propagates_panic_payload() {
        let pool = Pool::new(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run_chunks(10, |s, _| {
                if s == 0 {
                    panic!("boom in chunk");
                }
            });
        }));
        let payload = r.expect_err("panic must propagate to the caller");
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"boom in chunk"));
        // The pool survives the panic and keeps serving.
        let counter = AtomicUsize::new(0);
        pool.run_chunks(100, |s, e| {
            counter.fetch_add(e - s, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn submit_runs_detached_and_joins() {
        let pool = Pool::new(2);
        let (tx, rx) = channel();
        let h = pool.submit(move || {
            tx.send(42u32).unwrap();
        });
        assert_eq!(rx.recv().unwrap(), 42);
        assert_eq!(h.join(), TaskState::Done);
        assert_eq!(h.state(), TaskState::Done);
    }

    #[test]
    fn cancel_before_start_never_runs() {
        // One worker, blocked on a gate: the second task stays queued
        // and must be cancellable without ever running.
        let pool = Pool::new(1);
        let (gate_tx, gate_rx) = channel::<()>();
        let blocker = pool.submit(move || {
            gate_rx.recv().unwrap();
        });
        let ran = Arc::new(AtomicUsize::new(0));
        let ran2 = Arc::clone(&ran);
        let victim = pool.submit(move || {
            ran2.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(victim.cancel_or_join(), TaskState::Cancelled);
        gate_tx.send(()).unwrap();
        assert_eq!(blocker.join(), TaskState::Done);
        assert_eq!(ran.load(Ordering::SeqCst), 0, "cancelled task must never run");
        // cancel_or_join on a finished task degenerates to join.
        assert_eq!(blocker.cancel_or_join(), TaskState::Done);
    }

    #[test]
    fn high_water_never_exceeds_worker_count() {
        let pool = Pool::new(3);
        for _ in 0..5 {
            let counter = AtomicUsize::new(0);
            pool.run_chunks(300, |s, e| {
                counter.fetch_add(e - s, Ordering::SeqCst);
            });
            assert_eq!(counter.load(Ordering::SeqCst), 300);
        }
        assert!(pool.high_water() <= pool.n_workers(), "{}", pool.high_water());
        assert_eq!(pool.n_workers(), 3);
    }

    #[test]
    fn detached_panic_does_not_kill_workers() {
        let pool = Pool::new(1);
        let h = pool.submit(|| panic!("detached boom"));
        assert_eq!(h.join(), TaskState::Done);
        // The single worker survived and still executes work.
        let (tx, rx) = channel();
        pool.submit(move || tx.send(7u8).unwrap());
        assert_eq!(rx.recv().unwrap(), 7);
    }

    #[test]
    fn idle_worker_steals_from_pinned_workers_queue() {
        // Pin one of two workers on a gated detached job, then run a
        // chunk batch: run_chunks enlists *both* worker queues, so the
        // pinned worker's drain task sits queued behind the gate. The
        // free worker must steal it (the batch itself is finished by
        // the caller + free worker, so the stolen drain no-ops — but
        // the steal is what proves the backlog never waits on the
        // pinned worker).
        let pool = Pool::new(2);
        let (gate_tx, gate_rx) = channel::<()>();
        let blocker = pool.submit(move || {
            gate_rx.recv().unwrap();
        });
        while blocker.state() != TaskState::Running {
            std::thread::yield_now();
        }
        let before = pool.steals();
        let counter = AtomicUsize::new(0);
        pool.run_chunks(64, |s, e| {
            counter.fetch_add(e - s, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 64, "batch completes despite the pin");
        // The free worker loops back after the batch and must find (and
        // steal) the pinned worker's queued drain before it can park.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while pool.steals() == before {
            assert!(std::time::Instant::now() < deadline, "no steal observed");
            std::thread::yield_now();
        }
        gate_tx.send(()).unwrap();
        assert_eq!(blocker.join(), TaskState::Done);
        assert!(pool.steals() > before, "steal counter must advance");
        // With everything drained the workers park hungry: the failed
        // final scans show up in steal_fails (polled — parking happens
        // after the join returns to us).
        while pool.steal_fails() == 0 {
            assert!(std::time::Instant::now() < deadline, "no hungry park observed");
            std::thread::yield_now();
        }
    }

    #[test]
    fn global_pool_is_shared_and_sized_from_num_threads() {
        let p1 = global();
        let p2 = global();
        assert!(std::ptr::eq(p1, p2));
        assert_eq!(p1.n_workers(), crate::util::threads::num_threads().max(1));
    }
}
