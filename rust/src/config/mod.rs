//! Configuration system: a TOML-subset parser plus typed views.
//!
//! The offline image has no serde/toml crates, so this implements the
//! subset the launcher needs: `[section]` headers, `key = value` pairs,
//! `#` comments, string/number/bool scalars. See `examples/server.toml`
//! in the README for the schema.

pub mod parser;
pub mod schema;

pub use parser::ConfigFile;
pub use schema::{BlockingConfig, ChipConfig, NetSection, ServerConfig};
