//! Minimal TOML-subset parser: sections, scalar key/values, comments.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed config: `section -> key -> raw value string`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ConfigFile {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl ConfigFile {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<ConfigFile> {
        let mut cfg = ConfigFile::default();
        let mut section = String::new();
        cfg.sections.entry(String::new()).or_default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            let ctx = || format!("config line {}", lineno + 1);
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .with_context(|| format!("{}: unterminated section", ctx()))?;
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
            } else if let Some((k, v)) = line.split_once('=') {
                let key = k.trim().to_string();
                if key.is_empty() {
                    bail!("{}: empty key", ctx());
                }
                let val = unquote(v.trim());
                cfg.sections
                    .get_mut(&section)
                    .unwrap()
                    .insert(key, val.to_string());
            } else {
                bail!("{}: expected `key = value` or `[section]`", ctx());
            }
        }
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: &std::path::Path) -> Result<ConfigFile> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path:?}"))?;
        ConfigFile::parse(&text)
    }

    /// Raw value of `key` in `section`, if present.
    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(String::as_str)
    }

    /// Raw value of `key` in `section`, or `default` when absent.
    pub fn get_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).unwrap_or(default)
    }

    /// Parse `key` as a `usize`; `Ok(None)` when absent, `Err` on a
    /// malformed value.
    pub fn get_usize(&self, section: &str, key: &str) -> Result<Option<usize>> {
        self.get(section, key)
            .map(|v| v.parse().with_context(|| format!("[{section}] {key} = {v}: not an integer")))
            .transpose()
    }

    /// Parse `key` as an `i32` (same contract as [`ConfigFile::get_usize`]).
    pub fn get_i32(&self, section: &str, key: &str) -> Result<Option<i32>> {
        self.get(section, key)
            .map(|v| v.parse().with_context(|| format!("[{section}] {key} = {v}: not an integer")))
            .transpose()
    }

    /// Parse `key` as an `f64` (same contract as [`ConfigFile::get_usize`]).
    pub fn get_f64(&self, section: &str, key: &str) -> Result<Option<f64>> {
        self.get(section, key)
            .map(|v| v.parse().with_context(|| format!("[{section}] {key} = {v}: not a number")))
            .transpose()
    }

    /// Parse `key` as a literal `true` / `false` (same contract as
    /// [`ConfigFile::get_usize`]).
    pub fn get_bool(&self, section: &str, key: &str) -> Result<Option<bool>> {
        self.get(section, key)
            .map(|v| match v {
                "true" => Ok(true),
                "false" => Ok(false),
                other => bail!("[{section}] {key} = {other}: expected true/false"),
            })
            .transpose()
    }

    /// Names of every non-empty section, in arbitrary order.
    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(String::as_str).filter(|s| !s.is_empty())
    }
}

fn strip_comment(line: &str) -> &str {
    // No escape handling needed: values are simple scalars/paths.
    match line.find('#') {
        Some(i) if !in_quotes(line, i) => &line[..i],
        _ => line,
    }
}

fn in_quotes(line: &str, idx: usize) -> bool {
    line[..idx].matches('"').count() % 2 == 1
}

fn unquote(v: &str) -> &str {
    v.strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .unwrap_or(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top comment
top_key = 1

[server]
workers = 4
max_batch = 8
max_wait_ms = 2.5
backend = "cube-termwise"
strict = true   # inline comment

[chip]
name = "Ascend 910A"
"#;

    #[test]
    fn parses_sections_and_values() {
        let c = ConfigFile::parse(SAMPLE).unwrap();
        assert_eq!(c.get("", "top_key"), Some("1"));
        assert_eq!(c.get_usize("server", "workers").unwrap(), Some(4));
        assert_eq!(c.get_f64("server", "max_wait_ms").unwrap(), Some(2.5));
        assert_eq!(c.get("server", "backend"), Some("cube-termwise"));
        assert_eq!(c.get_bool("server", "strict").unwrap(), Some(true));
        assert_eq!(c.get("chip", "name"), Some("Ascend 910A"));
        assert_eq!(c.get("chip", "missing"), None);
        assert_eq!(c.sections().collect::<Vec<_>>(), vec!["chip", "server"]);
    }

    #[test]
    fn type_errors_are_reported() {
        let c = ConfigFile::parse("[s]\nx = notanumber").unwrap();
        assert!(c.get_usize("s", "x").is_err());
        assert!(c.get_bool("s", "x").is_err());
    }

    #[test]
    fn malformed_lines_error() {
        assert!(ConfigFile::parse("[unterminated").is_err());
        assert!(ConfigFile::parse("just a bare line").is_err());
        assert!(ConfigFile::parse("= novalue").is_err());
    }

    #[test]
    fn comment_inside_quotes_preserved() {
        let c = ConfigFile::parse("[s]\npath = \"/a#b/c\"").unwrap();
        assert_eq!(c.get("s", "path"), Some("/a#b/c"));
    }

    #[test]
    fn defaults() {
        let c = ConfigFile::parse("").unwrap();
        assert_eq!(c.get_or("server", "backend", "fp32"), "fp32");
    }
}
