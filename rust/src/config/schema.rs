//! Typed views over [`super::ConfigFile`].

use std::time::Duration;

use anyhow::{bail, Result};

use crate::config::parser::ConfigFile;
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::net::NetConfig;
use crate::coordinator::policy::PrecisionPolicy;
use crate::coordinator::server::ServiceConfig;
use crate::gemm::backend::{Backend, Schedule};
use crate::sim::blocking::BlockConfig;
use crate::sim::chip::Chip;

/// `[server]` section → [`ServiceConfig`].
#[derive(Debug, Clone, Default)]
pub struct ServerConfig(pub ServiceConfig);

impl ServerConfig {
    /// Build a [`ServiceConfig`] from the `[server]` section, starting
    /// from defaults and applying only the keys present.
    pub fn from_config(cfg: &ConfigFile) -> Result<ServerConfig> {
        let mut sc = ServiceConfig::default();
        if let Some(w) = cfg.get_usize("server", "workers")? {
            sc.n_workers = w;
        }
        if let Some(mb) = cfg.get_usize("server", "max_batch")? {
            if mb == 0 {
                bail!("[server] max_batch must be >= 1");
            }
            sc.batcher = BatcherConfig { max_batch: mb, ..sc.batcher };
        }
        if let Some(ms) = cfg.get_f64("server", "max_wait_ms")? {
            sc.batcher.max_wait = Duration::from_secs_f64(ms / 1e3);
        }
        if let Some(b) = cfg.get("server", "backend") {
            let backend = Backend::parse(b)
                .ok_or_else(|| anyhow::anyhow!("[server] backend = {b}: unknown backend"))?;
            sc.policy = PrecisionPolicy { default_backend: backend, ..sc.policy };
        }
        if let Some(e) = cfg.get_f64("server", "error_budget")? {
            sc.policy.error_budget = Some(e);
        }
        // `precision` is the user-facing spelling of the same knob: a
        // relative-error budget the policy satisfies with the cheapest
        // precision-emulation tier (fp16 → fp16×2 cube → bf16×3; the
        // full-range bf16 tiers replace the FP32 fallback out of
        // window). It wins over `error_budget` when both are present,
        // and per-request `submit_with_precision` overrides both.
        if let Some(p) = cfg.get_f64("server", "precision")? {
            if p <= 0.0 {
                bail!("[server] precision must be > 0");
            }
            sc.policy.error_budget = Some(p);
        }
        if let Some(mb) = cfg.get_usize("server", "prepack_cache_mb")? {
            // 0 = cache disabled (miss-through), see gemm::cache.
            sc.prepack_capacity = mb << 20;
        }
        // Legacy boolean schedule toggle; the richer `schedule` key
        // below wins when both are present. Like `schedule`, it is the
        // common knob: it sets both the raw-operand and the prepacked
        // path.
        if let Some(ov) = cfg.get_bool("server", "overlap")? {
            let schedule = if ov { Schedule::OverlapB } else { Schedule::Serial };
            sc.schedule = schedule;
            sc.schedule_prepacked = schedule;
        }
        if let Some(s) = cfg.get("server", "schedule") {
            let schedule = Schedule::parse(s).ok_or_else(|| {
                anyhow::anyhow!("[server] schedule = {s}: expected serial, overlap-b or overlap-ab")
            })?;
            sc.schedule = schedule;
            sc.schedule_prepacked = schedule;
        }
        // Per-path override: registered-weight (prepacked) requests can
        // run a different host schedule than raw operands. The default
        // is already `overlap-ab` (the A-stripe prefetch ring is the
        // measured win on the serving shape), so this key is mostly
        // used to *back off* — `schedule_prepacked = serial` — or to
        // diverge from a common `schedule` key, which sets both paths.
        if let Some(s) = cfg.get("server", "schedule_prepacked") {
            sc.schedule_prepacked = Schedule::parse(s).ok_or_else(|| {
                anyhow::anyhow!(
                    "[server] schedule_prepacked = {s}: expected serial, overlap-b or overlap-ab"
                )
            })?;
        }
        if let Some(d) = cfg.get_usize("server", "pipeline_depth")? {
            if d == 0 {
                bail!("[server] pipeline_depth must be >= 1");
            }
            sc.pipeline_depth = d;
        }
        if let Some(p) = cfg.get_usize("server", "pool_threads")? {
            // 0 = the shared global executor pool (the default).
            sc.pool_threads = p;
        }
        if let Some(ms) = cfg.get_f64("server", "request_timeout_ms")? {
            if ms < 0.0 {
                bail!("[server] request_timeout_ms must be >= 0");
            }
            // 0 = no deadline (the default), same as omitting the key.
            sc.request_timeout =
                if ms == 0.0 { None } else { Some(Duration::from_secs_f64(ms / 1e3)) };
        }
        if let Some(p) = cfg.get_usize("server", "max_pending")? {
            // 0 = unbounded admission (the default).
            sc.max_pending = p;
        }
        if let Some(r) = cfg.get_usize("server", "retries")? {
            // 0 = no retries; transient failures surface immediately.
            sc.retries = r;
        }
        if let Some(ms) = cfg.get_f64("server", "retry_backoff_ms")? {
            if ms < 0.0 {
                bail!("[server] retry_backoff_ms must be >= 0");
            }
            sc.retry_backoff = Duration::from_secs_f64(ms / 1e3);
        }
        // `[shards]` section → the column-shard router configuration
        // ([`crate::coordinator::shard`]); count < 2 keeps single-node
        // serving.
        if let Some(c) = cfg.get_usize("shards", "count")? {
            sc.shards.count = c;
        }
        if let Some(v) = cfg.get_usize("shards", "suspect_after")? {
            if v == 0 {
                bail!("[shards] suspect_after must be >= 1");
            }
            sc.shards.suspect_after = v as u32;
        }
        if let Some(v) = cfg.get_usize("shards", "dead_after")? {
            if v == 0 {
                bail!("[shards] dead_after must be >= 1");
            }
            sc.shards.dead_after = v as u32;
        }
        if sc.shards.dead_after < sc.shards.suspect_after {
            bail!(
                "[shards] dead_after ({}) must be >= suspect_after ({})",
                sc.shards.dead_after,
                sc.shards.suspect_after
            );
        }
        if let Some(v) = cfg.get_usize("shards", "retries")? {
            sc.shards.retries = v;
        }
        if let Some(ms) = cfg.get_f64("shards", "backoff_ms")? {
            if ms < 0.0 {
                bail!("[shards] backoff_ms must be >= 0");
            }
            sc.shards.backoff = Duration::from_secs_f64(ms / 1e3);
        }
        Ok(ServerConfig(sc))
    }
}

/// `[net]` section → [`NetConfig`] (the wire front door,
/// [`crate::coordinator::net`]).
#[derive(Debug, Clone, Default)]
pub struct NetSection(pub NetConfig);

impl NetSection {
    /// Build a [`NetConfig`] from the `[net]` section, starting from
    /// defaults and applying only the keys present. The `serve
    /// --listen ADDR` flag overrides `[net] listen`.
    pub fn from_config(cfg: &ConfigFile) -> Result<NetSection> {
        let mut nc = NetConfig::default();
        if let Some(l) = cfg.get("net", "listen") {
            if l.is_empty() {
                bail!("[net] listen must be host:port");
            }
            nc.listen = l.to_string();
        }
        if let Some(mb) = cfg.get_usize("net", "max_body_mb")? {
            if mb == 0 {
                bail!("[net] max_body_mb must be >= 1");
            }
            nc.max_body = mb << 20;
        }
        if let Some(ms) = cfg.get_f64("net", "read_timeout_ms")? {
            if ms <= 0.0 {
                bail!("[net] read_timeout_ms must be > 0");
            }
            nc.read_timeout = Duration::from_secs_f64(ms / 1e3);
        }
        if let Some(c) = cfg.get_usize("net", "max_connections")? {
            if c == 0 {
                bail!("[net] max_connections must be >= 1");
            }
            nc.max_connections = c;
        }
        Ok(NetSection(nc))
    }
}

/// `[chip]` section → [`Chip`] (named preset + optional overrides).
#[derive(Debug, Clone)]
pub struct ChipConfig(pub Chip);

impl ChipConfig {
    /// Resolve the `[chip]` preset and apply any numeric overrides.
    pub fn from_config(cfg: &ConfigFile) -> Result<ChipConfig> {
        let mut chip = match cfg.get_or("chip", "preset", "910a") {
            "910a" | "ascend-910a" => Chip::ascend_910a(),
            "910b3" | "ascend-910b3" => Chip::ascend_910b3_fp32(),
            other => bail!("[chip] preset = {other}: expected 910a or 910b3"),
        };
        if let Some(v) = cfg.get_f64("chip", "mem_bw_gbs")? {
            chip.mem_bw_gbs = v;
        }
        if let Some(v) = cfg.get_usize("chip", "n_cores")? {
            chip.n_cores = v as u32;
        }
        if let Some(v) = cfg.get_f64("chip", "mem_burst")? {
            chip.mem_burst = v;
        }
        Ok(ChipConfig(chip))
    }
}

/// `[blocking]` section → [`BlockConfig`].
#[derive(Debug, Clone)]
pub struct BlockingConfig(pub BlockConfig);

impl BlockingConfig {
    /// Read `[blocking]` block sizes (paper-best defaults) and validate
    /// them against `chip`'s Eq. (12) constraints.
    pub fn from_config(cfg: &ConfigFile, chip: &Chip) -> Result<BlockingConfig> {
        let bm = cfg.get_usize("blocking", "bm")?.unwrap_or(176);
        let bk = cfg.get_usize("blocking", "bk")?.unwrap_or(64);
        let bn = cfg.get_usize("blocking", "bn")?.unwrap_or(176);
        let block = BlockConfig::new(bm, bk, bn);
        if let Err(e) = block.validate(chip) {
            bail!("[blocking] infeasible on {}: {e}", chip.name);
        }
        Ok(BlockingConfig(block))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_section_roundtrip() {
        let cfg = ConfigFile::parse(
            "[server]\nworkers = 3\nmax_batch = 16\nmax_wait_ms = 5\nbackend = fp16\nerror_budget = 1e-3\nprepack_cache_mb = 64\noverlap = true\npipeline_depth = 3\npool_threads = 2",
        )
        .unwrap();
        let sc = ServerConfig::from_config(&cfg).unwrap().0;
        assert_eq!(sc.n_workers, 3);
        assert_eq!(sc.batcher.max_batch, 16);
        assert_eq!(sc.batcher.max_wait, Duration::from_millis(5));
        assert_eq!(sc.policy.default_backend, Backend::Fp16);
        assert_eq!(sc.policy.error_budget, Some(1e-3));
        assert_eq!(sc.prepack_capacity, 64 << 20);
        assert_eq!(sc.schedule, Schedule::OverlapB);
        assert_eq!(sc.pipeline_depth, 3);
        assert_eq!(sc.pool_threads, 2);
        // Defaults: workers track the host, capacity is nonzero, the
        // shared pool is used.
        let sc = ServerConfig::from_config(&ConfigFile::parse("").unwrap()).unwrap().0;
        assert!(sc.n_workers >= 1);
        assert!(sc.prepack_capacity > 0);
        assert_eq!(sc.pool_threads, 0);
        // overlap = false explicitly selects the serial schedule.
        let cfg = ConfigFile::parse("[server]\noverlap = false").unwrap();
        assert_eq!(ServerConfig::from_config(&cfg).unwrap().0.schedule, Schedule::Serial);
    }

    #[test]
    fn schedule_key_wins_over_legacy_overlap_toggle() {
        let cfg =
            ConfigFile::parse("[server]\noverlap = false\nschedule = overlap-ab").unwrap();
        let sc = ServerConfig::from_config(&cfg).unwrap().0;
        assert_eq!(sc.schedule, Schedule::OverlapAB);
        for name in ["serial", "overlap-b", "overlap-ab"] {
            let cfg = ConfigFile::parse(&format!("[server]\nschedule = {name}")).unwrap();
            let sc = ServerConfig::from_config(&cfg).unwrap().0;
            assert_eq!(sc.schedule.name(), name);
        }
        let bad = ConfigFile::parse("[server]\nschedule = warp-speed").unwrap();
        assert!(ServerConfig::from_config(&bad).is_err());
    }

    #[test]
    fn per_path_schedule_selection() {
        // The per-path key overrides only the prepacked path.
        let cfg =
            ConfigFile::parse("[server]\nschedule = serial\nschedule_prepacked = overlap-ab")
                .unwrap();
        let sc = ServerConfig::from_config(&cfg).unwrap().0;
        assert_eq!(sc.schedule, Schedule::Serial);
        assert_eq!(sc.schedule_prepacked, Schedule::OverlapAB);
        // The common knob sets both paths when the per-path key is
        // absent — and so does the legacy boolean toggle.
        let cfg = ConfigFile::parse("[server]\nschedule = overlap-b").unwrap();
        let sc = ServerConfig::from_config(&cfg).unwrap().0;
        assert_eq!(sc.schedule, Schedule::OverlapB);
        assert_eq!(sc.schedule_prepacked, Schedule::OverlapB);
        let cfg = ConfigFile::parse("[server]\noverlap = true").unwrap();
        let sc = ServerConfig::from_config(&cfg).unwrap().0;
        assert_eq!(sc.schedule_prepacked, Schedule::OverlapB);
        // Unknown values hard-error like the common key.
        let bad = ConfigFile::parse("[server]\nschedule_prepacked = warp-speed").unwrap();
        assert!(ServerConfig::from_config(&bad).is_err());
        // With no keys at all the prepacked path defaults to the
        // A-stripe prefetch ring, and the per-path key can back it off.
        let sc = ServerConfig::from_config(&ConfigFile::parse("").unwrap()).unwrap().0;
        assert_eq!(sc.schedule_prepacked, Schedule::OverlapAB);
        let cfg = ConfigFile::parse("[server]\nschedule_prepacked = serial").unwrap();
        let sc = ServerConfig::from_config(&cfg).unwrap().0;
        assert_eq!(sc.schedule_prepacked, Schedule::Serial);
    }

    #[test]
    fn precision_key_sets_the_error_budget() {
        let cfg = ConfigFile::parse("[server]\nprecision = 1e-7").unwrap();
        let sc = ServerConfig::from_config(&cfg).unwrap().0;
        assert_eq!(sc.policy.error_budget, Some(1e-7));
        // The user-facing key wins over the legacy spelling.
        let cfg = ConfigFile::parse("[server]\nerror_budget = 1e-3\nprecision = 1e-7").unwrap();
        let sc = ServerConfig::from_config(&cfg).unwrap().0;
        assert_eq!(sc.policy.error_budget, Some(1e-7));
        let bad = ConfigFile::parse("[server]\nprecision = 0").unwrap();
        assert!(ServerConfig::from_config(&bad).is_err());
        let bad = ConfigFile::parse("[server]\nprecision = -1e-6").unwrap();
        assert!(ServerConfig::from_config(&bad).is_err());
    }

    #[test]
    fn zero_pipeline_depth_rejected() {
        let cfg = ConfigFile::parse("[server]\npipeline_depth = 0").unwrap();
        assert!(ServerConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn zero_prepack_cache_mb_disables_the_cache() {
        let cfg = ConfigFile::parse("[server]\nprepack_cache_mb = 0").unwrap();
        let sc = ServerConfig::from_config(&cfg).unwrap().0;
        assert_eq!(sc.prepack_capacity, 0, "0 MB = cache disabled (miss-through)");
    }

    #[test]
    fn chip_presets_and_overrides() {
        let cfg = ConfigFile::parse("[chip]\npreset = 910b3\nmem_bw_gbs = 2000").unwrap();
        let chip = ChipConfig::from_config(&cfg).unwrap().0;
        assert_eq!(chip.n_cores, 20);
        assert_eq!(chip.mem_bw_gbs, 2000.0);
        assert!(ChipConfig::from_config(&ConfigFile::parse("[chip]\npreset = h100").unwrap()).is_err());
    }

    #[test]
    fn blocking_validated_against_chip() {
        let chip = Chip::ascend_910a();
        let good = ConfigFile::parse("[blocking]\nbm = 96\nbk = 64\nbn = 96").unwrap();
        assert_eq!(BlockingConfig::from_config(&good, &chip).unwrap().0, BlockConfig::new(96, 64, 96));
        let bad = ConfigFile::parse("[blocking]\nbm = 100\nbk = 64\nbn = 96").unwrap();
        assert!(BlockingConfig::from_config(&bad, &chip).is_err());
        // Defaults are the paper's best block.
        let empty = ConfigFile::parse("").unwrap();
        assert_eq!(
            BlockingConfig::from_config(&empty, &chip).unwrap().0,
            BlockConfig::paper_best()
        );
    }

    #[test]
    fn zero_max_batch_rejected() {
        let cfg = ConfigFile::parse("[server]\nmax_batch = 0").unwrap();
        assert!(ServerConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn resilience_knobs_roundtrip() {
        let cfg = ConfigFile::parse(
            "[server]\nrequest_timeout_ms = 250\nmax_pending = 64\nretries = 3\nretry_backoff_ms = 0.5",
        )
        .unwrap();
        let sc = ServerConfig::from_config(&cfg).unwrap().0;
        assert_eq!(sc.request_timeout, Some(Duration::from_millis(250)));
        assert_eq!(sc.max_pending, 64);
        assert_eq!(sc.retries, 3);
        assert_eq!(sc.retry_backoff, Duration::from_micros(500));
        // Defaults: no deadline, unbounded admission, the stock retry
        // budget; 0 explicitly disables the deadline too.
        let sc = ServerConfig::from_config(&ConfigFile::parse("").unwrap()).unwrap().0;
        assert_eq!(sc.request_timeout, None);
        assert_eq!(sc.max_pending, 0);
        let cfg = ConfigFile::parse("[server]\nrequest_timeout_ms = 0").unwrap();
        assert_eq!(ServerConfig::from_config(&cfg).unwrap().0.request_timeout, None);
        // Negative durations are rejected.
        let bad = ConfigFile::parse("[server]\nrequest_timeout_ms = -1").unwrap();
        assert!(ServerConfig::from_config(&bad).is_err());
        let bad = ConfigFile::parse("[server]\nretry_backoff_ms = -1").unwrap();
        assert!(ServerConfig::from_config(&bad).is_err());
    }

    #[test]
    fn net_section_roundtrip_and_validation() {
        let cfg = ConfigFile::parse(
            "[net]\nlisten = \"0.0.0.0:8080\"\nmax_body_mb = 8\nread_timeout_ms = 500\nmax_connections = 16",
        )
        .unwrap();
        let nc = NetSection::from_config(&cfg).unwrap().0;
        assert_eq!(nc.listen, "0.0.0.0:8080");
        assert_eq!(nc.max_body, 8 << 20);
        assert_eq!(nc.read_timeout, Duration::from_millis(500));
        assert_eq!(nc.max_connections, 16);
        // Defaults: loopback ephemeral port, sane caps.
        let nc = NetSection::from_config(&ConfigFile::parse("").unwrap()).unwrap().0;
        assert_eq!(nc.listen, "127.0.0.1:0");
        assert!(nc.max_body > 0 && nc.max_connections > 0);
        for bad in [
            "[net]\nmax_body_mb = 0",
            "[net]\nread_timeout_ms = 0",
            "[net]\nread_timeout_ms = -5",
            "[net]\nmax_connections = 0",
        ] {
            let cfg = ConfigFile::parse(bad).unwrap();
            assert!(NetSection::from_config(&cfg).is_err(), "{bad}");
        }
    }

    #[test]
    fn shards_section_roundtrip_and_validation() {
        let cfg = ConfigFile::parse(
            "[shards]\ncount = 4\nsuspect_after = 2\ndead_after = 5\nretries = 2\nbackoff_ms = 1",
        )
        .unwrap();
        let sh = ServerConfig::from_config(&cfg).unwrap().0.shards;
        assert_eq!(sh.count, 4);
        assert_eq!(sh.suspect_after, 2);
        assert_eq!(sh.dead_after, 5);
        assert_eq!(sh.retries, 2);
        assert_eq!(sh.backoff, Duration::from_millis(1));
        // Default: sharding off.
        let sh = ServerConfig::from_config(&ConfigFile::parse("").unwrap()).unwrap().0.shards;
        assert_eq!(sh.count, 0);
        // Thresholds must be >= 1 and ordered.
        for bad in [
            "[shards]\nsuspect_after = 0",
            "[shards]\ndead_after = 0",
            "[shards]\nsuspect_after = 5\ndead_after = 2",
            "[shards]\nbackoff_ms = -1",
        ] {
            let cfg = ConfigFile::parse(bad).unwrap();
            assert!(ServerConfig::from_config(&cfg).is_err(), "{bad}");
        }
    }
}
