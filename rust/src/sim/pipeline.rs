//! Per-iteration pipeline timing (Sec. 5.1.2, Fig. 7).
//!
//! One *iteration* processes one resident A block (b_m×b_k) against one
//! streamed B block (b_k×b_n) on the cube. The model:
//!
//! * `T_comp` — cube cycles: one 16×16×16 MAC tile per cycle, plus a
//!   fixed fill/drain bubble per block GEMM (the "poor L0A/L0B
//!   utilization at small tiles" of Sec. 6.3).
//! * `T_b` — streaming the B block main-memory → L1 at the per-core
//!   achievable bandwidth, plus a DMA descriptor-setup cost.
//! * `T_l0` — L1 → L0A/L0B staging at on-chip bandwidth (pipelined by
//!   the MTE; enters only through the `max` in double-buffered mode and
//!   additively in single-buffered mode at reduced weight).
//! * `C` amortization — the C tile is read+written through UB once per
//!   k-group (Eq. 9's `C_rw` term), spread over `N_fused` iterations.
//!
//! Single buffer: `T_iter = T_comp + T_b + T_l0 + sync` (the paper's
//! `T_comp + T_mem`). Double buffer: `T_iter = max(T_comp, T_b, T_l0) +
//! residual + sync` (the paper's `T_comp + α·T_mem`), where the
//! non-overlapped residual is `ALPHA_NONOVERLAP·setup` under the
//! default paper-anchored calibration ([`IterTiming::of`]) or
//! `α·T_b` under a calibration measured from the executed engine's
//! stage timings ([`IterTiming::from_measured`], fed by
//! `crate::gemm::overlap` — see EXPERIMENTS.md §Overlap).

use crate::sim::blocking::BlockConfig;
use crate::sim::chip::Chip;

/// L1 B-buffer strategy (Fig. 7).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Buffering {
    /// One L1 B buffer: stream and compute serialize.
    Single,
    /// Two L1 B buffers: the next B block streams under compute.
    Double,
}

impl Buffering {
    /// Stable identifier used in reports.
    pub fn name(self) -> &'static str {
        match self {
            Buffering::Single => "single-buffer",
            Buffering::Double => "double-buffer",
        }
    }
}

/// Fixed cube fill/drain bubble per block GEMM, in cycles.
pub const CUBE_STARTUP_CYCLES: f64 = 16.0;
/// Fraction of the DMA setup cost that double buffering cannot hide
/// (the paper's non-overlapped α in `T_comp + α·T_mem`). This is the
/// *default* calibration guess; [`IterTiming::from_measured`] replaces
/// it with a value derived from the executed engine's stage timings.
pub const ALPHA_NONOVERLAP: f64 = 0.25;

/// Per-iteration timing decomposition, in cycles.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IterTiming {
    /// Cube compute cycles (MAC tiles + fill/drain bubble).
    pub t_comp: f64,
    /// Main-memory → L1 streaming cycles for the B block.
    pub t_b_stream: f64,
    /// L1 → L0A/L0B staging cycles.
    pub t_l0: f64,
    /// Per-iteration share of the C tile's UB read+write (Eq. 9).
    pub c_amortized: f64,
    /// Fixed per-iteration synchronization overhead, in cycles.
    pub sync: f64,
    /// DMA setup cost (cycles) — the α residual source in double mode.
    pub dma_setup: f64,
    /// Non-overlapped fraction α — informational/reporting only: the
    /// term actually charged in double-buffered mode is
    /// [`IterTiming::nonoverlap_residual`], so mutate α through
    /// [`IterTiming::from_measured`] (which derives the residual), not
    /// by assigning this field.
    pub alpha: f64,
    /// Cycles of memory span left on the critical path in
    /// double-buffered mode: `ALPHA_NONOVERLAP · dma_setup` from
    /// [`IterTiming::of`] (the paper-calibrated residual — only the DMA
    /// descriptor setup escapes a functioning double buffer), or
    /// `α · t_b_stream` from [`IterTiming::from_measured`] — there α is
    /// the *measured* unhidden fraction of the whole B span, so a
    /// failed overlap (α → 1) correctly degrades the model to
    /// single-buffer performance instead of perturbing only the tiny
    /// setup constant.
    pub nonoverlap_residual: f64,
}

impl IterTiming {
    /// Build the timing terms for one iteration of `block` on `chip`.
    /// `n_fused` is the A-group residency (Eq. 8) used to amortize the C
    /// tile read+write.
    pub fn of(chip: &Chip, block: BlockConfig, n_fused: u64) -> IterTiming {
        let eb = chip.elem_bytes as f64;
        let core_bw = chip.core_bw_bytes_per_cycle();
        let macs_per_cycle = chip.cube_macs_per_cycle as f64;

        let tiles = (block.bm * block.bk * block.bn) as f64 / macs_per_cycle;
        let t_comp = tiles + CUBE_STARTUP_CYCLES;

        let b_bytes = (block.bk * block.bn) as f64 * eb;
        let t_b_stream = b_bytes / core_bw + chip.dma_setup_cycles;

        let l0_bytes = ((block.bm * block.bk) + (block.bk * block.bn)) as f64 * eb;
        let t_l0 = l0_bytes / chip.l0_bw_bytes_per_cycle;

        // C tile: read + write of bm×bn FP32 once per k-group.
        let c_bytes = 2.0 * (block.bm * block.bn) as f64 * 4.0;
        let c_amortized = c_bytes / core_bw / (n_fused.max(1) as f64);

        IterTiming {
            t_comp,
            t_b_stream,
            t_l0,
            c_amortized,
            sync: chip.sync_cycles,
            dma_setup: chip.dma_setup_cycles,
            alpha: ALPHA_NONOVERLAP,
            nonoverlap_residual: ALPHA_NONOVERLAP * chip.dma_setup_cycles,
        }
    }

    /// Like [`IterTiming::of`], but with the non-overlapped fraction α
    /// taken from *measured* engine stage timings instead of the
    /// hard-coded [`ALPHA_NONOVERLAP`] — the calibration path the
    /// ROADMAP's "double-buffered overlap driven by real engine timings"
    /// item asks for. `measured_alpha` usually comes from
    /// [`IterTiming::alpha_from_measured`] over the staged-driver
    /// breakdown (`crate::gemm::overlap`, EXPERIMENTS.md §Overlap);
    /// it is clamped to `[0, 1]`.
    pub fn from_measured(
        chip: &Chip,
        block: BlockConfig,
        n_fused: u64,
        measured_alpha: f64,
    ) -> IterTiming {
        let mut t = IterTiming::of(chip, block, n_fused);
        t.alpha = measured_alpha.clamp(0.0, 1.0);
        // The measured α is the unhidden fraction of the *whole* B
        // span (the engine inversion divides by T_mem), so it charges
        // against t_b_stream — not the dma_setup constant the
        // hard-coded calibration perturbs. α = 1 therefore collapses
        // double-buffered performance to single-buffered, which is
        // exactly what a measured total overlap failure means.
        t.nonoverlap_residual = t.alpha * t.t_b_stream;
        t
    }

    /// Derive the non-overlapped fraction α from measured wall times of
    /// the executed engine, by inverting the paper's double-buffer model
    /// `T_double = max(T_comp, T_mem) + α·T_mem`:
    ///
    /// ```text
    /// α = (T_overlapped − max(T_comp, T_mem)) / T_mem, clamped to [0, 1]
    /// ```
    ///
    /// `t_comp` is the compute-path span (pack-A + micro-kernel + C
    /// update), `t_mem` the hidden span (B-panel preparation), and
    /// `t_overlapped` the measured wall time of the overlapped pipeline
    /// — all over the same GEMM, any common unit. Returns 0 when
    /// `t_mem` is not positive (nothing to hide, nothing left over).
    pub fn alpha_from_measured(t_comp: f64, t_mem: f64, t_overlapped: f64) -> f64 {
        Self::alpha_from_measured_raw(t_comp, t_mem, t_overlapped).clamp(0.0, 1.0)
    }

    /// The pre-clamp model inversion behind [`alpha_from_measured`] —
    /// measurement noise can push it outside `[0, 1]` (it divides the
    /// serial-vs-overlapped difference by the usually-small `t_mem`),
    /// which makes it the right quantity to *record* for diagnosing a
    /// calibration, while the clamped variant is the one to *apply*.
    ///
    /// [`alpha_from_measured`]: IterTiming::alpha_from_measured
    pub fn alpha_from_measured_raw(t_comp: f64, t_mem: f64, t_overlapped: f64) -> f64 {
        if t_mem <= 0.0 {
            return 0.0;
        }
        (t_overlapped - t_comp.max(t_mem)) / t_mem
    }

    /// Total cycles of one iteration under the given buffering strategy.
    pub fn cycles(&self, buffering: Buffering) -> f64 {
        match buffering {
            Buffering::Single => {
                // The paper's T_comp + T_mem: the L1 B-block stream is
                // serialized with compute. L1→L0 staging is pipelined by
                // the MTE in both modes (the single/double distinction is
                // about the L1 B buffers), so `t_l0` only matters when it
                // exceeds the serialized span.
                (self.t_comp + self.t_b_stream).max(self.t_l0) + self.c_amortized + self.sync
            }
            Buffering::Double => {
                // max(T_comp, T_mem) plus the non-overlapped residual
                // (the paper's α·T_mem term): ALPHA_NONOVERLAP·dma_setup
                // by default, α·t_b_stream under a measured calibration
                // ([`IterTiming::from_measured`]).
                let overlapped = self.t_comp.max(self.t_b_stream).max(self.t_l0);
                overlapped + self.nonoverlap_residual + self.c_amortized + self.sync
            }
        }
    }

    /// Cube utilization of one iteration (useful-MAC cycles / total).
    pub fn utilization(&self, buffering: Buffering, block: BlockConfig, chip: &Chip) -> f64 {
        let useful = (block.bm * block.bk * block.bn) as f64 / chip.cube_macs_per_cycle as f64;
        useful / self.cycles(buffering)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn double_never_slower_than_single() {
        let chip = Chip::ascend_910a();
        for cfg in crate::sim::blocking::feasible_blocks(&chip, 256) {
            let t = IterTiming::of(&chip, cfg, cfg.n_fused(&chip));
            assert!(
                t.cycles(Buffering::Double) <= t.cycles(Buffering::Single) + 1e-9,
                "cfg {cfg:?}"
            );
        }
    }

    #[test]
    fn paper_best_config_utilizations() {
        // Calibration anchors (Sec. 6.3): single ≈ 41.7/85.3 = 0.489,
        // double ≈ 65.3/85.3 = 0.766 cube utilization per GEMM pass.
        let chip = Chip::ascend_910a();
        let cfg = BlockConfig::paper_best();
        let t = IterTiming::of(&chip, cfg, cfg.n_fused(&chip));
        let u_single = t.utilization(Buffering::Single, cfg, &chip);
        let u_double = t.utilization(Buffering::Double, cfg, &chip);
        assert!((u_single - 0.489).abs() < 0.05, "single util {u_single}");
        assert!((u_double - 0.766).abs() < 0.05, "double util {u_double}");
    }

    #[test]
    fn small_blocks_have_poor_utilization() {
        // Fig. 11 low points: 16³ blocks leave the cube mostly idle.
        let chip = Chip::ascend_910a();
        let cfg = BlockConfig::new(16, 16, 16);
        let t = IterTiming::of(&chip, cfg, cfg.n_fused(&chip));
        assert!(t.utilization(Buffering::Double, cfg, &chip) < 0.05);
    }

    #[test]
    fn alpha_from_measured_inverts_the_double_buffer_model() {
        // Fully hidden: overlapped time equals the dominant span.
        assert_eq!(IterTiming::alpha_from_measured(8.0, 2.0, 8.0), 0.0);
        // Fully serial: overlapped time is comp + mem → α = 1.
        assert_eq!(IterTiming::alpha_from_measured(8.0, 2.0, 10.0), 1.0);
        // Halfway.
        let a = IterTiming::alpha_from_measured(8.0, 2.0, 9.0);
        assert!((a - 0.5).abs() < 1e-12, "{a}");
        // Memory-bound iteration: the max switches operands.
        let a = IterTiming::alpha_from_measured(2.0, 8.0, 10.0);
        assert!((a - 0.25).abs() < 1e-12, "{a}");
        // Clamped: a faster-than-model overlap or no mem span → 0.
        assert_eq!(IterTiming::alpha_from_measured(8.0, 2.0, 7.0), 0.0);
        assert_eq!(IterTiming::alpha_from_measured(8.0, 0.0, 99.0), 0.0);
        // Worse-than-serial noise clamps at 1.
        assert_eq!(IterTiming::alpha_from_measured(8.0, 2.0, 99.0), 1.0);
        // The raw variant exposes the same inversion unclamped (the
        // diagnostic the bench records as blocked/alpha_raw).
        assert_eq!(IterTiming::alpha_from_measured_raw(8.0, 2.0, 99.0), 45.5);
        assert_eq!(IterTiming::alpha_from_measured_raw(8.0, 2.0, 7.0), -0.5);
        assert_eq!(IterTiming::alpha_from_measured_raw(8.0, 0.0, 99.0), 0.0);
    }

    #[test]
    fn from_measured_replaces_the_hardcoded_alpha() {
        let chip = Chip::ascend_910a();
        let cfg = BlockConfig::paper_best();
        let n_fused = cfg.n_fused(&chip);
        let default = IterTiming::of(&chip, cfg, n_fused);
        assert_eq!(default.alpha, ALPHA_NONOVERLAP);
        assert_eq!(default.nonoverlap_residual, ALPHA_NONOVERLAP * chip.dma_setup_cycles);
        let lo = IterTiming::from_measured(&chip, cfg, n_fused, 0.0);
        let hi = IterTiming::from_measured(&chip, cfg, n_fused, 1.0);
        assert_eq!(lo.alpha, 0.0);
        assert_eq!(lo.nonoverlap_residual, 0.0);
        assert_eq!(hi.alpha, 1.0);
        // A measured α charges against the whole B stream, not just the
        // DMA setup constant.
        assert_eq!(hi.nonoverlap_residual, hi.t_b_stream);
        // Only the Double mode responds to α, monotonically.
        let d = |t: &IterTiming| t.cycles(Buffering::Double);
        assert!(d(&lo) < d(&default) && d(&default) < d(&hi));
        assert_eq!(lo.cycles(Buffering::Single), hi.cycles(Buffering::Single));
        // Out-of-range measurements are clamped, not trusted.
        assert_eq!(IterTiming::from_measured(&chip, cfg, n_fused, -3.0).alpha, 0.0);
        assert_eq!(IterTiming::from_measured(&chip, cfg, n_fused, 7.0).alpha, 1.0);
        // A measured total overlap failure (α = 1) collapses Double to
        // Single performance for this compute-bound config — never
        // slower, and visibly worse than the default calibration. That
        // sensitivity is the point of the measured path.
        assert!((d(&hi) - hi.cycles(Buffering::Single)).abs() < 1e-9);
        let u = |t: &IterTiming| t.utilization(Buffering::Double, cfg, &chip);
        assert!(u(&hi) < u(&default) * 0.8, "{} vs {}", u(&hi), u(&default));
    }

    #[test]
    fn compute_dominates_best_config() {
        let chip = Chip::ascend_910a();
        let cfg = BlockConfig::paper_best();
        let t = IterTiming::of(&chip, cfg, cfg.n_fused(&chip));
        assert!(t.t_comp > t.t_b_stream, "{t:?}");
        assert!(t.t_comp > t.t_l0, "{t:?}");
    }
}
