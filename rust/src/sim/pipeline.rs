//! Per-iteration pipeline timing (Sec. 5.1.2, Fig. 7).
//!
//! One *iteration* processes one resident A block (b_m×b_k) against one
//! streamed B block (b_k×b_n) on the cube. The model:
//!
//! * `T_comp` — cube cycles: one 16×16×16 MAC tile per cycle, plus a
//!   fixed fill/drain bubble per block GEMM (the "poor L0A/L0B
//!   utilization at small tiles" of Sec. 6.3).
//! * `T_b` — streaming the B block main-memory → L1 at the per-core
//!   achievable bandwidth, plus a DMA descriptor-setup cost.
//! * `T_l0` — L1 → L0A/L0B staging at on-chip bandwidth (pipelined by
//!   the MTE; enters only through the `max` in double-buffered mode and
//!   additively in single-buffered mode at reduced weight).
//! * `C` amortization — the C tile is read+written through UB once per
//!   k-group (Eq. 9's `C_rw` term), spread over `N_fused` iterations.
//!
//! Single buffer: `T_iter = T_comp + T_b + T_l0 + sync` (the paper's
//! `T_comp + T_mem`). Double buffer: `T_iter = max(T_comp, T_b, T_l0) +
//! α·setup + sync` (the paper's `T_comp + α·T_mem` with the
//! non-overlapped fraction α as calibration).

use crate::sim::blocking::BlockConfig;
use crate::sim::chip::Chip;

/// L1 B-buffer strategy (Fig. 7).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Buffering {
    Single,
    Double,
}

impl Buffering {
    pub fn name(self) -> &'static str {
        match self {
            Buffering::Single => "single-buffer",
            Buffering::Double => "double-buffer",
        }
    }
}

/// Fixed cube fill/drain bubble per block GEMM, in cycles.
pub const CUBE_STARTUP_CYCLES: f64 = 16.0;
/// Fraction of the DMA setup cost that double buffering cannot hide
/// (the paper's non-overlapped α in `T_comp + α·T_mem`).
pub const ALPHA_NONOVERLAP: f64 = 0.25;

/// Per-iteration timing decomposition, in cycles.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IterTiming {
    pub t_comp: f64,
    pub t_b_stream: f64,
    pub t_l0: f64,
    pub c_amortized: f64,
    pub sync: f64,
    /// DMA setup cost (cycles) — the α residual source in double mode.
    pub dma_setup: f64,
}

impl IterTiming {
    /// Build the timing terms for one iteration of `block` on `chip`.
    /// `n_fused` is the A-group residency (Eq. 8) used to amortize the C
    /// tile read+write.
    pub fn of(chip: &Chip, block: BlockConfig, n_fused: u64) -> IterTiming {
        let eb = chip.elem_bytes as f64;
        let core_bw = chip.core_bw_bytes_per_cycle();
        let macs_per_cycle = chip.cube_macs_per_cycle as f64;

        let tiles = (block.bm * block.bk * block.bn) as f64 / macs_per_cycle;
        let t_comp = tiles + CUBE_STARTUP_CYCLES;

        let b_bytes = (block.bk * block.bn) as f64 * eb;
        let t_b_stream = b_bytes / core_bw + chip.dma_setup_cycles;

        let l0_bytes = ((block.bm * block.bk) + (block.bk * block.bn)) as f64 * eb;
        let t_l0 = l0_bytes / chip.l0_bw_bytes_per_cycle;

        // C tile: read + write of bm×bn FP32 once per k-group.
        let c_bytes = 2.0 * (block.bm * block.bn) as f64 * 4.0;
        let c_amortized = c_bytes / core_bw / (n_fused.max(1) as f64);

        IterTiming {
            t_comp,
            t_b_stream,
            t_l0,
            c_amortized,
            sync: chip.sync_cycles,
            dma_setup: chip.dma_setup_cycles,
        }
    }

    /// Total cycles of one iteration under the given buffering strategy.
    pub fn cycles(&self, buffering: Buffering) -> f64 {
        match buffering {
            Buffering::Single => {
                // The paper's T_comp + T_mem: the L1 B-block stream is
                // serialized with compute. L1→L0 staging is pipelined by
                // the MTE in both modes (the single/double distinction is
                // about the L1 B buffers), so `t_l0` only matters when it
                // exceeds the serialized span.
                (self.t_comp + self.t_b_stream).max(self.t_l0) + self.c_amortized + self.sync
            }
            Buffering::Double => {
                // max(T_comp, T_mem) plus the non-overlapped slice of the
                // DMA setup (the paper's α·T_mem residual).
                let overlapped = self.t_comp.max(self.t_b_stream).max(self.t_l0);
                overlapped + ALPHA_NONOVERLAP * self.dma_setup + self.c_amortized + self.sync
            }
        }
    }

    /// Cube utilization of one iteration (useful-MAC cycles / total).
    pub fn utilization(&self, buffering: Buffering, block: BlockConfig, chip: &Chip) -> f64 {
        let useful = (block.bm * block.bk * block.bn) as f64 / chip.cube_macs_per_cycle as f64;
        useful / self.cycles(buffering)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn double_never_slower_than_single() {
        let chip = Chip::ascend_910a();
        for cfg in crate::sim::blocking::feasible_blocks(&chip, 256) {
            let t = IterTiming::of(&chip, cfg, cfg.n_fused(&chip));
            assert!(
                t.cycles(Buffering::Double) <= t.cycles(Buffering::Single) + 1e-9,
                "cfg {cfg:?}"
            );
        }
    }

    #[test]
    fn paper_best_config_utilizations() {
        // Calibration anchors (Sec. 6.3): single ≈ 41.7/85.3 = 0.489,
        // double ≈ 65.3/85.3 = 0.766 cube utilization per GEMM pass.
        let chip = Chip::ascend_910a();
        let cfg = BlockConfig::paper_best();
        let t = IterTiming::of(&chip, cfg, cfg.n_fused(&chip));
        let u_single = t.utilization(Buffering::Single, cfg, &chip);
        let u_double = t.utilization(Buffering::Double, cfg, &chip);
        assert!((u_single - 0.489).abs() < 0.05, "single util {u_single}");
        assert!((u_double - 0.766).abs() < 0.05, "double util {u_double}");
    }

    #[test]
    fn small_blocks_have_poor_utilization() {
        // Fig. 11 low points: 16³ blocks leave the cube mostly idle.
        let chip = Chip::ascend_910a();
        let cfg = BlockConfig::new(16, 16, 16);
        let t = IterTiming::of(&chip, cfg, cfg.n_fused(&chip));
        assert!(t.utilization(Buffering::Double, cfg, &chip) < 0.05);
    }

    #[test]
    fn compute_dominates_best_config() {
        let chip = Chip::ascend_910a();
        let cfg = BlockConfig::paper_best();
        let t = IterTiming::of(&chip, cfg, cfg.n_fused(&chip));
        assert!(t.t_comp > t.t_b_stream, "{t:?}");
        assert!(t.t_comp > t.t_l0, "{t:?}");
    }
}
