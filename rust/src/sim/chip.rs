//! Hardware descriptions for the simulator.
//!
//! Capacity and throughput numbers for the Ascend 910A come from the
//! paper (Sec. 5.1, 6.1 and Eq. 12); the pipeline-overhead constants
//! (`dma_setup_cycles`, `sync_cycles`, `l0_bandwidth`, `mem_burst`) are
//! calibration parameters fitted once so the simulated best-block
//! throughput matches the paper's measured 41.7 (single-buffer) and
//! 65.3 TFLOP/s (double-buffer) anchors — see EXPERIMENTS.md §Calibration.

/// A simulated NPU.
#[derive(Debug, Clone, PartialEq)]
pub struct Chip {
    /// Human-readable platform name (shown in reports and bench records).
    pub name: &'static str,
    /// Number of AI cores.
    pub n_cores: u32,
    /// Core clock in GHz (cycles below are in core cycles).
    pub freq_ghz: f64,
    /// MACs per cycle per core of the matrix engine at its native
    /// element type (Cube 16×16×16 = 4096 for FP16 on 910A).
    pub cube_macs_per_cycle: u64,
    /// Bytes per element of the matrix engine's native input type.
    pub elem_bytes: u32,
    /// Aggregate main-memory bandwidth in GB/s.
    pub mem_bw_gbs: f64,
    /// L1 buffer capacity per core, in bytes.
    pub l1_bytes: u64,
    /// L0A capacity constraint on `b_m·b_k`, in *elements* (Eq. 12).
    pub l0a_elems: u64,
    /// L0B capacity constraint on `b_k·b_n`, in *elements* (Eq. 12).
    pub l0b_elems: u64,
    /// Combined L0C + UB constraint: `b_m·b_n·6 ≤ ub_budget_bytes` (Eq. 12).
    pub ub_budget_bytes: u64,
    /// Block alignment required by the cube (Eq. 12): 16.
    pub align: usize,

    // --- pipeline calibration parameters ---
    /// Fixed DMA descriptor-setup cost per transfer, in cycles.
    pub dma_setup_cycles: f64,
    /// Per-iteration synchronization / instruction-issue overhead that is
    /// never hidden by double buffering, in cycles.
    pub sync_cycles: f64,
    /// L1 → L0A/L0B bandwidth per core, bytes per cycle.
    pub l0_bw_bytes_per_cycle: f64,
    /// Burst factor: a single core's achievable share of main-memory
    /// bandwidth relative to `mem_bw / n_cores` (cores do not all DMA in
    /// the same cycle, so a streaming core sees more than 1/n_cores).
    pub mem_burst: f64,
}

impl Chip {
    /// Huawei Ascend 910A — the paper's primary platform: 32 AI cores at
    /// 1 GHz, 256 TFLOP/s FP16 Cube peak, 1.2 TB/s, 1 MB L1 per core,
    /// no native FP32 matrix units.
    pub fn ascend_910a() -> Chip {
        Chip {
            name: "Ascend 910A",
            n_cores: 32,
            freq_ghz: 1.0,
            // The Cube is a 16×16×16 (4096-MAC) array; the published
            // 256 TFLOP/s @ 32 cores/1 GHz implies a sustained issue rate
            // of 4000 MAC/cycle (97.7%), which we use directly so the
            // model peak equals the paper's peak exactly.
            cube_macs_per_cycle: 4000,
            elem_bytes: 2,
            mem_bw_gbs: 1200.0,
            l1_bytes: 1024 * 1024,
            l0a_elems: 64 * 256,
            l0b_elems: 64 * 256,
            ub_budget_bytes: 248 * 1024,
            align: 16,
            dma_setup_cycles: 40.0,
            sync_cycles: 20.0,
            l0_bw_bytes_per_cycle: 256.0,
            mem_burst: 1.7,
        }
    }

    /// Huawei Ascend 910B3 — 20 AI cores at 1.8 GHz, native FP32 GEMM
    /// with a 73.73 TFLOP/s theoretical peak, 1.6 TB/s, half the L1 per
    /// core (Sec. 6.1). Used as the CANN-FP32 cross-platform comparator
    /// of Fig. 12.
    pub fn ascend_910b3_fp32() -> Chip {
        // 73.73e12 FLOP/s = 2 * macs/cycle * 20 cores * 1.8e9 ->
        // macs/cycle = 1024 (a 16x16x4 FP32 configuration).
        Chip {
            name: "Ascend 910B3 (FP32 CANN)",
            n_cores: 20,
            freq_ghz: 1.8,
            cube_macs_per_cycle: 1024,
            elem_bytes: 4,
            mem_bw_gbs: 1600.0,
            l1_bytes: 512 * 1024,
            l0a_elems: 64 * 256 / 2,
            l0b_elems: 64 * 256 / 2,
            ub_budget_bytes: 192 * 1024,
            align: 16,
            dma_setup_cycles: 40.0,
            sync_cycles: 20.0,
            l0_bw_bytes_per_cycle: 512.0,
            mem_burst: 1.7,
        }
    }

    /// The machine this process runs on, described in the same cache
    /// vocabulary as the NPUs so the Eq. (8)/(9)/(12) blocking machinery
    /// can drive the *executed* blocked GEMM engine
    /// (`crate::gemm::blocked`), not just the simulator figures.
    ///
    /// Mapping (conservative generic x86-64/aarch64 numbers; per-core
    /// L1d ≈ 32 KB, per-core L2 ≈ 512 KB):
    ///
    /// * `l1_bytes` — the per-core L2 slice holding the packed panels
    ///   (the paper's L1 buffer role);
    /// * `l0a_elems` / `l0b_elems` — caps on `b_m·b_k` / `b_k·b_n` so a
    ///   packed A block and the resident B panel each stay ≤ 64 KB
    ///   single-component (≤ 128 KB for the dual high/low cube format)
    ///   and their micro-panels stream through L1d;
    /// * `ub_budget_bytes` — caps `b_m·b_n·6`, bounding the C tile a
    ///   thread revisits per k block (the L0C/UB role);
    /// * `align` — 16, which also keeps blocks divisible by the
    ///   micro-kernel geometry (`MR = 4`, `NR = 8`, derived from the
    ///   vector register file by [`crate::sim::blocking::micro_tile`]).
    ///
    /// `cube_macs_per_cycle` follows the kernel lane the dispatcher
    /// selected ([`crate::gemm::kernels::active_lane`]): two FMA issue
    /// ports × the lane's f32 width (AVX2 16, NEON 8, scalar 2). The
    /// throughput/bandwidth fields are rough host figures; they feed
    /// roofline diagnostics only — block *selection* uses capacities and
    /// the traffic model alone, so the chosen blocks are identical on
    /// every lane (part of the cross-schedule bit-identity story).
    pub fn host_cpu() -> Chip {
        let macs = match crate::gemm::kernels::active_lane() {
            crate::gemm::kernels::Lane::Avx2 => 16,
            crate::gemm::kernels::Lane::Neon => 8,
            crate::gemm::kernels::Lane::Scalar => 2,
        };
        Chip {
            name: "host-cpu",
            n_cores: crate::util::threads::num_threads() as u32,
            freq_ghz: 3.0,
            cube_macs_per_cycle: macs,
            elem_bytes: 4,
            mem_bw_gbs: 30.0,
            l1_bytes: 512 * 1024,
            l0a_elems: 16 * 1024,
            l0b_elems: 16 * 1024,
            ub_budget_bytes: 128 * 1024,
            align: 16,
            dma_setup_cycles: 0.0,
            sync_cycles: 0.0,
            l0_bw_bytes_per_cycle: 64.0,
            mem_burst: 1.0,
        }
    }

    /// Peak matrix-engine throughput in TFLOP/s (native element type).
    pub fn peak_tflops(&self) -> f64 {
        2.0 * self.cube_macs_per_cycle as f64 * self.n_cores as f64 * self.freq_ghz * 1e9 / 1e12
    }

    /// The paper's FP32-equivalent peak for the three-GEMM decomposition:
    /// native FP16 peak / 3 (Table 2 note). Only meaningful for FP16
    /// chips running SGEMM-cube.
    pub fn fp32_equiv_peak_tflops(&self) -> f64 {
        self.peak_tflops() / 3.0
    }

    /// Cycles per second.
    pub fn hz(&self) -> f64 {
        self.freq_ghz * 1e9
    }

    /// Achievable streaming bandwidth of one core, bytes/cycle.
    pub fn core_bw_bytes_per_cycle(&self) -> f64 {
        self.mem_bw_gbs * 1e9 / self.n_cores as f64 * self.mem_burst / self.hz()
    }

    /// Aggregate bandwidth in bytes/second.
    pub fn mem_bw_bytes_per_sec(&self) -> f64 {
        self.mem_bw_gbs * 1e9
    }

    /// L1 capacity in native elements — the unit Eq. (8) counts in.
    pub fn l1_elems(&self) -> u64 {
        self.l1_bytes / self.elem_bytes as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_matches_published_910a() {
        let c = Chip::ascend_910a();
        assert!((c.peak_tflops() - 256.0).abs() < 1e-9);
        assert!((c.fp32_equiv_peak_tflops() - 256.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn peak_matches_published_910b3() {
        let c = Chip::ascend_910b3_fp32();
        assert!((c.peak_tflops() - 73.728).abs() < 0.01, "{}", c.peak_tflops());
    }

    #[test]
    fn l1_element_capacity() {
        let c = Chip::ascend_910a();
        assert_eq!(c.l1_elems(), 524_288); // 1 MB of FP16
        let b = Chip::ascend_910b3_fp32();
        assert_eq!(b.l1_elems(), 131_072); // 512 KB of FP32
    }

    #[test]
    fn host_cpu_admits_feasible_blocks() {
        let c = Chip::host_cpu();
        assert!(c.n_cores >= 1);
        assert_eq!(c.l1_elems(), 131_072); // 512 KB of f32
        let blocks = crate::sim::blocking::feasible_blocks(&c, 256);
        assert!(!blocks.is_empty());
        // Alignment divides the micro-kernel geometry.
        assert_eq!(c.align % 8, 0);
    }

    #[test]
    fn core_bandwidth_sane() {
        let c = Chip::ascend_910a();
        let per_core = c.core_bw_bytes_per_cycle();
        // 1.2 TB/s / 32 cores * burst 1.7 = 63.75 B/cycle @ 1 GHz.
        assert!((per_core - 63.75).abs() < 1e-9, "{per_core}");
    }
}
