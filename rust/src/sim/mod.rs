//! Cycle-approximate performance simulator of the Ascend DaVinci AI core
//! (Fig. 4 of the paper).
//!
//! The paper's throughput results (Figs. 10–12, Table 2) were measured on
//! Ascend 910A hardware, which this reproduction does not have. The
//! substitution (DESIGN.md §2) implements the paper's *own* performance
//! model — L1-aware blocking (Eq. 8–9, 12), the roofline bound
//! (Eq. 10–11) and the single/double-buffered pipeline bound
//! `T_comp + α·T_mem` (Sec. 5.1.2) — as a parametric simulator whose
//! constants are instantiated from the published 910A/910B3 figures.
//!
//! * [`chip`] — hardware descriptions (910A, 910B3, custom).
//! * [`blocking`] — block-size constraints, `N_fused`, fusion factor `f`,
//!   the traffic model and the optimal `b_m` derivation.
//! * [`roofline`] — operational intensity and the roofline ceiling.
//! * [`pipeline`] — per-iteration timing for single/double buffering.
//! * [`executor`] — whole-kernel simulation for one FP16 GEMM pass and
//!   for the full three-term SGEMM-cube (split + 3 GEMMs + reconstruct).

pub mod blocking;
pub mod chip;
pub mod executor;
pub mod pipeline;
pub mod roofline;

pub use blocking::{BlockConfig, Traffic};
pub use chip::Chip;
pub use executor::{simulate_gemm, simulate_sgemm_cube, SimResult};
pub use pipeline::Buffering;
pub use roofline::{operational_intensity, roofline_bound};
