//! Roofline model on the main-memory ↔ L1 path (Eq. 10–11).

use crate::sim::blocking::{BlockConfig, GemmShape, Traffic};
use crate::sim::chip::Chip;

/// Eq. (10): operational intensity in FLOPs/byte, under the paper's
/// convention that traffic is charged at FP32 element sizes
/// (`s_A = s_B = s_C = 4`) and the FLOP count is the FP32-equivalent
/// `2·m·n·k` of a single GEMM.
pub fn operational_intensity(shape: GemmShape, block: BlockConfig, chip: &Chip) -> f64 {
    let traffic = Traffic::of(shape, block, chip);
    shape.flops() / traffic.total_bytes(4.0, 4.0, 4.0)
}

/// Eq. (11): `P_roof = min(P_peak, β·OI)` in TFLOP/s, with `P_peak` the
/// FP32-equivalent peak (native FP16 peak / 3) and `β` the sustained
/// main-memory → L1 bandwidth.
pub fn roofline_bound(chip: &Chip, oi: f64) -> f64 {
    let p_peak = chip.fp32_equiv_peak_tflops();
    let bw_tflops = chip.mem_bw_bytes_per_sec() * oi / 1e12;
    p_peak.min(bw_tflops)
}

/// Roofline bound against the chip's *native* peak (used for the 910B3
/// FP32 comparator, where no three-GEMM convention applies).
pub fn roofline_bound_native(chip: &Chip, oi: f64) -> f64 {
    let p_peak = chip.peak_tflops();
    let bw_tflops = chip.mem_bw_bytes_per_sec() * oi / 1e12;
    p_peak.min(bw_tflops)
}

/// The knee point: the OI at which the bandwidth roof meets the compute
/// roof (FP32-equivalent convention).
pub fn knee_oi(chip: &Chip) -> f64 {
    chip.fp32_equiv_peak_tflops() * 1e12 / chip.mem_bw_bytes_per_sec()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knee_point_910a() {
        // 85.33 TFLOP/s / 1.2 TB/s ≈ 71.1 FLOPs/byte.
        let chip = Chip::ascend_910a();
        let knee = knee_oi(&chip);
        assert!((knee - 71.1).abs() < 0.2, "knee={knee}");
    }

    #[test]
    fn paper_configs_are_compute_bound() {
        // Paper Fig. 10: all measured OI values lie above the knee.
        let chip = Chip::ascend_910a();
        let shape = GemmShape::new(4096, 4096, 4096);
        for cfg in [
            BlockConfig::paper_best(),
            BlockConfig::new(96, 64, 96),
            BlockConfig::new(128, 64, 128),
        ] {
            let oi = operational_intensity(shape, cfg, &chip);
            assert!(oi > knee_oi(&chip), "cfg {cfg:?} OI={oi}");
            assert_eq!(roofline_bound(&chip, oi), chip.fp32_equiv_peak_tflops());
        }
    }

    #[test]
    fn small_oi_is_bandwidth_bound() {
        let chip = Chip::ascend_910a();
        let bound = roofline_bound(&chip, 10.0);
        assert!((bound - 12.0).abs() < 1e-9); // 1.2 TB/s * 10 F/B = 12 TF/s
        assert!(bound < chip.fp32_equiv_peak_tflops());
    }

    #[test]
    fn oi_peaks_near_optimal_bm() {
        // Eq. 9/10: the B term falls with b_m while the C term grows, so
        // OI is maximized near b_m,opt ≈ 88 (rounded to 96) — exactly the
        // trade-off behind the paper's optimal-b_m derivation.
        let chip = Chip::ascend_910a();
        let shape = GemmShape::new(8192, 4096, 8192);
        let oi = |bm: usize| operational_intensity(shape, BlockConfig::new(bm, 64, bm.min(176)), &chip);
        assert!(oi(96) > oi(48), "{} vs {}", oi(96), oi(48));
        assert!(oi(96) > oi(176), "{} vs {}", oi(96), oi(176));
    }

    #[test]
    fn native_roofline_uses_full_peak() {
        let chip = Chip::ascend_910b3_fp32();
        assert_eq!(roofline_bound_native(&chip, 1e6), chip.peak_tflops());
    }
}
