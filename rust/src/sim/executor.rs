//! Whole-kernel simulation: loop structure of Algorithm 1 evaluated
//! analytically (all iterations of a phase are identical, so the
//! discrete-event reduction is exact up to edge blocks, which are
//! handled by ceiling arithmetic).

use crate::sim::blocking::{BlockConfig, GemmShape, Traffic};
use crate::sim::chip::Chip;
use crate::sim::pipeline::{Buffering, IterTiming};
use crate::sim::roofline;

/// Result of simulating a kernel on the model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimResult {
    /// Wall-clock seconds.
    pub seconds: f64,
    /// Throughput of the *logical* FP32 GEMM: `2·m·n·k / seconds`, in
    /// TFLOP/s. For SGEMM-cube this is the paper's FP32-equivalent
    /// metric; for a single FP16/FP32 pass it is the native throughput.
    pub tflops: f64,
    /// Cube utilization relative to the native peak during GEMM phases.
    pub utilization: f64,
    /// Operational intensity on the main-memory↔L1 path (Eq. 10).
    pub oi: f64,
    /// Roofline ceiling for this configuration (Eq. 11), TFLOP/s,
    /// using the same convention as `tflops`.
    pub roof: f64,
}

/// Count with ceiling division.
#[inline]
fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Simulate one GEMM pass (`C += A·B` at the chip's native element type)
/// with the Algorithm-1 loop structure. Returns the wall time in seconds
/// and the average cube utilization.
pub fn simulate_gemm_pass(
    chip: &Chip,
    shape: GemmShape,
    block: BlockConfig,
    buffering: Buffering,
) -> (f64, f64) {
    block
        .validate(chip)
        .unwrap_or_else(|e| panic!("infeasible block {block:?} on {}: {e}", chip.name));
    let n_fused = block.n_fused(chip).max(1);
    let timing = IterTiming::of(chip, block, n_fused);

    // Loop counts (ceiling arithmetic handles edge blocks).
    let row_blocks = ceil_div(shape.m, block.bm); // distributed over cores
    let k_chunks = ceil_div(shape.k, block.bk);
    let k_groups = ceil_div(k_chunks, n_fused as usize);
    let n_blocks = ceil_div(shape.n, block.bn);

    // Per-core assignment: the busiest core gets the ceiling share.
    let rows_per_core = ceil_div(row_blocks, chip.n_cores as usize);

    // A-group staging: N_fused blocks of bm×bk from main memory, once
    // per (row, k-group); amortized but not overlapped (conservative).
    let a_group_bytes =
        (n_fused as usize * block.bm * block.bk) as f64 * chip.elem_bytes as f64;
    let t_a_group = a_group_bytes / chip.core_bw_bytes_per_cycle()
        + chip.dma_setup_cycles * n_fused as f64;

    let iter_cycles = timing.cycles(buffering);
    let mut core_cycles = 0.0f64;
    for _ in 0..rows_per_core {
        // Each k-group: stage A group, then sweep n-blocks; each n-block
        // runs up to N_fused iterations (fewer in the last group).
        let mut chunks_left = k_chunks;
        for _ in 0..k_groups {
            let in_group = chunks_left.min(n_fused as usize);
            chunks_left -= in_group;
            core_cycles += t_a_group * (in_group as f64 / n_fused as f64);
            core_cycles += n_blocks as f64 * in_group as f64 * iter_cycles;
        }
    }

    let seconds = core_cycles / chip.hz();
    let useful_mac_cycles = rows_per_core as f64
        * k_chunks as f64
        * n_blocks as f64
        * (block.bm * block.bk * block.bn) as f64
        / chip.cube_macs_per_cycle as f64;
    let utilization = useful_mac_cycles / core_cycles;
    (seconds, utilization)
}

/// Simulate a single native GEMM (FP16 HGEMM on 910A, or FP32 CANN GEMM
/// on 910B3). `tflops`/`roof` are native-convention numbers.
pub fn simulate_gemm(
    chip: &Chip,
    shape: GemmShape,
    block: BlockConfig,
    buffering: Buffering,
) -> SimResult {
    let (seconds, utilization) = simulate_gemm_pass(chip, shape, block, buffering);
    let oi = native_oi(shape, block, chip);
    SimResult {
        seconds,
        tflops: shape.flops() / seconds / 1e12,
        utilization,
        oi,
        roof: roofline::roofline_bound_native(chip, oi),
    }
}

/// Native-element OI (traffic charged at the chip's element size; C at 4B).
fn native_oi(shape: GemmShape, block: BlockConfig, chip: &Chip) -> f64 {
    let t = Traffic::of(shape, block, chip);
    let eb = chip.elem_bytes as f64;
    shape.flops() / t.total_bytes(eb, eb, 4.0)
}

/// Simulate the full SGEMM-cube kernel: operand splitting, the three
/// dominant FP16 GEMM passes and the FP32 reconstruction, as deployed on
/// the FP16 chip. Returns the FP32-equivalent result (Eq. 10 convention:
/// `2·m·n·k` FLOPs over the total time).
pub fn simulate_sgemm_cube(
    chip: &Chip,
    shape: GemmShape,
    block: BlockConfig,
    buffering: Buffering,
) -> SimResult {
    let (t_pass, util) = simulate_gemm_pass(chip, shape, block, buffering);

    // Split pass (vector units, bandwidth bound, all cores): read A and B
    // in FP32 and write high+low FP16 pairs: (4 + 2 + 2) bytes/element.
    let split_bytes = 8.0 * (shape.m * shape.k + shape.k * shape.n) as f64;
    // Reconstruction: the termwise combine streams the three C terms and
    // writes the final C: (3 + 1) × 4 bytes + one read of the partial
    // sums ≈ 20 bytes/element of C.
    let recon_bytes = 20.0 * (shape.m * shape.n) as f64;
    // The vector work overlaps the Cube pipeline almost entirely: the
    // reconstruction is fused into the GEMM epilogue through UB (its C
    // traffic is already charged via `c_amortized`) and the split of the
    // next tile proceeds while the Cube computes. Only a calibrated
    // non-overlapped fraction reaches the critical path.
    const VECTOR_NONOVERLAP: f64 = 0.25;
    let t_vector =
        VECTOR_NONOVERLAP * (split_bytes + recon_bytes) / chip.mem_bw_bytes_per_sec();

    let seconds = 3.0 * t_pass + t_vector;
    let oi = roofline_oi_fp32_equiv(shape, block, chip);
    SimResult {
        seconds,
        tflops: shape.flops() / seconds / 1e12,
        utilization: util * (3.0 * t_pass) / seconds,
        oi,
        roof: roofline::roofline_bound(chip, oi),
    }
}

/// Eq. (10) exactly as the paper states it: FP32-equivalent FLOPs over
/// traffic charged at `s_A = s_B = s_C = 4` bytes.
fn roofline_oi_fp32_equiv(shape: GemmShape, block: BlockConfig, chip: &Chip) -> f64 {
    roofline::operational_intensity(shape, block, chip)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn big_shape() -> GemmShape {
        // 5632 = 32 cores × 176: every core gets exactly one row block,
        // matching the fully-occupied regime of the paper's Fig. 11.
        GemmShape::new(5632, 4096, 5632)
    }

    #[test]
    fn cube_single_buffer_matches_paper_anchor() {
        // Paper Fig. 11(a): single-buffer peak 41.7 TFLOP/s.
        let chip = Chip::ascend_910a();
        let r = simulate_sgemm_cube(&chip, big_shape(), BlockConfig::paper_best(), Buffering::Single);
        assert!((r.tflops - 41.7).abs() < 3.0, "single-buffer {:.1} TFLOP/s", r.tflops);
    }

    #[test]
    fn cube_double_buffer_matches_paper_anchor() {
        // Paper Fig. 11(b): double-buffer peak 65.3 TFLOP/s = 77% of 85.3.
        let chip = Chip::ascend_910a();
        let r = simulate_sgemm_cube(&chip, big_shape(), BlockConfig::paper_best(), Buffering::Double);
        assert!((r.tflops - 65.3).abs() < 3.5, "double-buffer {:.1} TFLOP/s", r.tflops);
        let frac = r.tflops / chip.fp32_equiv_peak_tflops();
        assert!((frac - 0.77).abs() < 0.05, "fraction {frac:.3}");
    }

    #[test]
    fn double_buffer_gain_about_57_percent() {
        // Paper: 41.7 -> 65.3 is a 57% gain.
        let chip = Chip::ascend_910a();
        let cfg = BlockConfig::paper_best();
        let s = simulate_sgemm_cube(&chip, big_shape(), cfg, Buffering::Single);
        let d = simulate_sgemm_cube(&chip, big_shape(), cfg, Buffering::Double);
        let gain = d.tflops / s.tflops - 1.0;
        assert!((gain - 0.57).abs() < 0.12, "gain {gain:.2}");
    }

    #[test]
    fn hgemm_pass_faster_than_cube() {
        // One FP16 pass must be ~3x the FP32-equivalent cube throughput.
        let chip = Chip::ascend_910a();
        let cfg = BlockConfig::paper_best();
        let h = simulate_gemm(&chip, big_shape(), cfg, Buffering::Double);
        let c = simulate_sgemm_cube(&chip, big_shape(), cfg, Buffering::Double);
        let ratio = h.tflops / c.tflops;
        assert!((ratio - 3.0).abs() < 0.35, "ratio {ratio:.2}");
    }

    #[test]
    fn b3_fp32_near_its_peak() {
        // Fig. 12(b): CANN FP32 on 910B3 ≈ 63 TFLOP/s stable.
        let chip = Chip::ascend_910b3_fp32();
        let cfg = BlockConfig::new(96, 64, 96);
        let shape = GemmShape::new(3840, 4096, 3840);
        let r = simulate_gemm(&chip, shape, cfg, Buffering::Double);
        assert!((r.tflops - 63.0).abs() < 5.0, "910B3 {:.1} TFLOP/s", r.tflops);
    }

    #[test]
    fn throughput_grows_with_mn_then_saturates() {
        // Fig. 12(a) shape: increasing m=n pushes throughput up.
        let chip = Chip::ascend_910a();
        let cfg = BlockConfig::paper_best();
        let small = simulate_sgemm_cube(&chip, GemmShape::new(704, 2816, 704), cfg, Buffering::Double);
        let large = simulate_sgemm_cube(&chip, GemmShape::new(5632, 2816, 5632), cfg, Buffering::Double);
        assert!(large.tflops > small.tflops);
        assert!(large.tflops > 60.0, "{}", large.tflops);
    }

    #[test]
    fn utilization_below_one_and_consistent() {
        let chip = Chip::ascend_910a();
        let r = simulate_gemm(&chip, big_shape(), BlockConfig::paper_best(), Buffering::Double);
        assert!(r.utilization > 0.0 && r.utilization < 1.0);
        // tflops should equal utilization * native peak (up to A-staging).
        let expect = r.utilization * chip.peak_tflops();
        assert!((r.tflops - expect).abs() / expect < 0.1, "{} vs {}", r.tflops, expect);
    }

    #[test]
    #[should_panic(expected = "infeasible block")]
    fn infeasible_block_panics() {
        let chip = Chip::ascend_910a();
        let _ = simulate_gemm(&chip, big_shape(), BlockConfig::new(256, 128, 256), Buffering::Double);
    }

    #[test]
    fn oi_above_knee_for_paper_configs() {
        let chip = Chip::ascend_910a();
        let r = simulate_sgemm_cube(&chip, big_shape(), BlockConfig::paper_best(), Buffering::Double);
        assert!(r.oi > roofline::knee_oi(&chip));
        assert_eq!(r.roof, chip.fp32_equiv_peak_tflops());
    }
}
