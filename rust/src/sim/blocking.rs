//! L1-aware blocking: constraints, fusion, traffic (Sec. 5.1.1).
//!
//! * Eq. (8): `N_fused = floor((L1 - 2·b_k·b_n) / (b_m·b_k))` — how many
//!   A blocks fit in L1 next to the double-buffered B blocks.
//! * Eq. (9): main-memory ↔ L1 traffic of A, B and C.
//! * Eq. (12): hardware feasibility constraints.
//! * `b_m,opt = sqrt(f·L1 / (2·N_core))` — the analytic optimum derived
//!   by minimizing Eq. (9) in `b_m` (≈ 88 on 910A, rounded to 96).
//! * [`micro_tile`] — the innermost tier of the same capacity argument:
//!   the register-file budget that fixes the host micro-kernel's
//!   `MR × NR` tile, mirroring how Eq. (12) sizes the cache blocks.

use crate::sim::chip::Chip;

/// GEMM problem shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmShape {
    /// Rows of A and C.
    pub m: usize,
    /// Inner (contraction) dimension.
    pub k: usize,
    /// Columns of B and C.
    pub n: usize,
}

impl GemmShape {
    /// Bundle an `(m, k, n)` problem shape.
    pub fn new(m: usize, k: usize, n: usize) -> GemmShape {
        GemmShape { m, k, n }
    }

    /// FLOP count of one GEMM at this shape (`2·m·n·k`).
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }
}

/// A blocking configuration `(b_m, b_k, b_n)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockConfig {
    /// Row-block size `b_m`.
    pub bm: usize,
    /// Inner-dimension block size `b_k`.
    pub bk: usize,
    /// Column-block size `b_n`.
    pub bn: usize,
}

/// Why a block configuration is infeasible (Eq. 12).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ConstraintViolation {
    /// Block sizes are zero or not multiples of the chip alignment.
    Alignment {
        /// Required alignment (elements).
        align: usize,
        /// Offending `b_m`.
        bm: usize,
        /// Offending `b_k`.
        bk: usize,
        /// Offending `b_n`.
        bn: usize,
    },
    /// `b_m·b_k` exceeds the L0A buffer.
    L0aCapacity {
        /// Elements requested.
        got: u64,
        /// L0A capacity in elements.
        cap: u64,
    },
    /// `b_k·b_n` exceeds the L0B buffer.
    L0bCapacity {
        /// Elements requested.
        got: u64,
        /// L0B capacity in elements.
        cap: u64,
    },
    /// The C tile traffic exceeds the L0C/UB byte budget.
    UbCapacity {
        /// Bytes requested (`b_m·b_n·6`).
        got: u64,
        /// UB budget in bytes.
        cap: u64,
    },
    /// L1 cannot hold one A block next to double-buffered B blocks.
    L1Capacity,
}

impl std::fmt::Display for ConstraintViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConstraintViolation::Alignment { align, bm, bk, bn } => {
                write!(f, "block sizes must be positive multiples of {align}: ({bm}, {bk}, {bn})")
            }
            ConstraintViolation::L0aCapacity { got, cap } => {
                write!(f, "b_m*b_k = {got} exceeds L0A capacity {cap}")
            }
            ConstraintViolation::L0bCapacity { got, cap } => {
                write!(f, "b_k*b_n = {got} exceeds L0B capacity {cap}")
            }
            ConstraintViolation::UbCapacity { got, cap } => {
                write!(f, "b_m*b_n*6 = {got} exceeds L0C/UB budget {cap}")
            }
            ConstraintViolation::L1Capacity => {
                write!(f, "L1 cannot hold one A block plus double-buffered B blocks")
            }
        }
    }
}

impl std::error::Error for ConstraintViolation {}

impl BlockConfig {
    /// Bundle a `(b_m, b_k, b_n)` blocking configuration.
    pub fn new(bm: usize, bk: usize, bn: usize) -> BlockConfig {
        BlockConfig { bm, bk, bn }
    }

    /// The paper's best configuration on 910A (Sec. 6.3).
    pub fn paper_best() -> BlockConfig {
        BlockConfig::new(176, 64, 176)
    }

    /// Validate against the hardware constraints of Eq. (12).
    pub fn validate(&self, chip: &Chip) -> Result<(), ConstraintViolation> {
        let (bm, bk, bn) = (self.bm, self.bk, self.bn);
        let a = chip.align;
        if bm == 0 || bk == 0 || bn == 0 || bm % a != 0 || bk % a != 0 || bn % a != 0 {
            return Err(ConstraintViolation::Alignment { align: a, bm, bk, bn });
        }
        let l0a = (bm * bk) as u64;
        if l0a > chip.l0a_elems {
            return Err(ConstraintViolation::L0aCapacity { got: l0a, cap: chip.l0a_elems });
        }
        let l0b = (bk * bn) as u64;
        if l0b > chip.l0b_elems {
            return Err(ConstraintViolation::L0bCapacity { got: l0b, cap: chip.l0b_elems });
        }
        let ub = (bm * bn * 6) as u64;
        if ub > chip.ub_budget_bytes {
            return Err(ConstraintViolation::UbCapacity { got: ub, cap: chip.ub_budget_bytes });
        }
        if self.n_fused(chip) < 1 {
            return Err(ConstraintViolation::L1Capacity);
        }
        Ok(())
    }

    /// Eq. (8): number of A blocks resident in L1 alongside the two
    /// B buffers (L1 measured in elements of the chip's native type).
    pub fn n_fused(&self, chip: &Chip) -> u64 {
        let l1 = chip.l1_elems() as i64;
        let need_b = 2 * (self.bk * self.bn) as i64;
        let per_a = (self.bm * self.bk) as i64;
        ((l1 - need_b) / per_a).max(0) as u64
    }

    /// The fusion efficiency factor `f = N_fused·b_m·b_k / L1`
    /// (0.92 ≤ f ≤ 1 in the paper's experiments).
    pub fn fusion_factor(&self, chip: &Chip) -> f64 {
        self.n_fused(chip) as f64 * (self.bm * self.bk) as f64 / chip.l1_elems() as f64
    }
}

/// Eq. (9): memory traffic (in *elements*) between main memory and L1.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Traffic {
    /// A is read once: `m·k`.
    pub a_read: f64,
    /// B reloads across cores: `m·k·n / (N_core·b_m)`.
    pub b_read: f64,
    /// C read+write through UB per k-group: `2·m·k·n·b_m / (f·L1)`.
    pub c_rw: f64,
}

impl Traffic {
    /// Evaluate Eq. (9) for one GEMM pass.
    pub fn of(shape: GemmShape, block: BlockConfig, chip: &Chip) -> Traffic {
        let (m, k, n) = (shape.m as f64, shape.k as f64, shape.n as f64);
        let f = block.fusion_factor(chip).max(1e-9);
        Traffic {
            a_read: m * k,
            b_read: m * k * n / (chip.n_cores as f64 * block.bm as f64),
            c_rw: 2.0 * m * k * n * block.bm as f64 / (f * chip.l1_elems() as f64),
        }
    }

    /// Total elements moved.
    pub fn total_elems(&self) -> f64 {
        self.a_read + self.b_read + self.c_rw
    }

    /// Total bytes moved given per-matrix element sizes `(s_A, s_B, s_C)`
    /// (Eq. 10 uses 4 bytes each under the FP32-equivalent convention).
    pub fn total_bytes(&self, s_a: f64, s_b: f64, s_c: f64) -> f64 {
        self.a_read * s_a + self.b_read * s_b + self.c_rw * s_c
    }

    /// Eq. (9) mapped onto the host blocked loop nest executed by
    /// `crate::gemm::blocked` (`b_n` → `b_k` → `b_m`, packed panels).
    ///
    /// The roles of the paper's operands are mirrored on the CPU: the
    /// packed B panel is the cache-resident operand (the paper's fused A
    /// group in L1), the packed A row panels stream through it, and the C
    /// tile accumulates in place once per k block. Per-operand traffic
    /// between main memory and the panel cache, in elements:
    ///
    /// * A is re-read once per `b_n` column block: `m·k·⌈n/b_n⌉`;
    /// * B is packed exactly once: `k·n`;
    /// * C is read + written once per `b_k` block: `2·m·n·⌈k/b_k⌉`.
    pub fn host_blocked(shape: GemmShape, block: BlockConfig) -> Traffic {
        let (m, k, n) = (shape.m as f64, shape.k as f64, shape.n as f64);
        let n_blocks = shape.n.div_ceil(block.bn) as f64;
        let k_blocks = shape.k.div_ceil(block.bk) as f64;
        Traffic {
            a_read: m * k * n_blocks,
            b_read: k * n,
            c_rw: 2.0 * m * n * k_blocks,
        }
    }
}

/// The analytic optimum `b_m,opt = sqrt(f·L1 / (2·N_core))` (Sec. 5.1.1),
/// taking `f` at a representative 0.95.
pub fn optimal_bm(chip: &Chip) -> f64 {
    let f = 0.95;
    (f * chip.l1_elems() as f64 / (2.0 * chip.n_cores as f64)).sqrt()
}

/// Round `x` to the nearest feasible multiple of the chip alignment
/// (at least one alignment unit).
pub fn round_to_align(x: f64, chip: &Chip) -> usize {
    let a = chip.align as f64;
    ((x / a).round().max(1.0) as usize) * chip.align
}

/// Derive the micro-kernel tile `(MR, NR)` from a vector register file —
/// the register-tier analogue of the Eq. (12) cache constraints.
///
/// `regs` is the number of architectural vector registers and `lanes`
/// the f32 lanes per register. The tile row is sized so the B panel
/// step is read as whole vectors: `NR = lanes·⌈8/lanes⌉` (8 f32 per
/// row — one AVX2 YMM, or two NEON q-registers). `MR` is then the
/// largest power of two whose **cube** working set still fits:
///
/// ```text
/// 2·MR·vpr  (high·high + correction accumulator planes)
///  + 2·vpr  (the b_h and b_l step vectors)
///  + 1      (the broadcast A value)
///           ≤ regs,    where vpr = NR / lanes
/// ```
///
/// The cube kernel is the binding case — the plain f32 kernel holds
/// half the accumulators. The 128/256-bit register files land on the
/// same **narrow** `(4, 8)` tile (AVX2: 16 regs × 8 lanes; NEON:
/// 32 regs × 4 lanes) that [`crate::gemm::pack::MR`] /
/// [`crate::gemm::pack::NR`] pin and the scalar lane reuses for format
/// compatibility. The AVX-512 file (32 regs × 16 lanes) genuinely
/// differs: the 16-lane row rounds `NR` up to one whole ZMM vector and
/// the doubled register count carries `MR = 8`, giving the **wide**
/// `(8, 16)` tile pinned as
/// [`crate::gemm::pack::MAX_MR`]/[`crate::gemm::pack::MAX_NR`].
/// Panel geometry therefore follows the lane
/// ([`crate::gemm::kernels::Lane::tile_dims`]); the derivations are
/// pinned by const asserts in the SIMD kernels and by tests here.
pub fn micro_tile(regs: usize, lanes: usize) -> (usize, usize) {
    assert!(regs >= 4 && lanes >= 1, "degenerate register file ({regs} regs, {lanes} lanes)");
    let nr = lanes * 8usize.div_ceil(lanes);
    let vpr = nr / lanes;
    let mut mr = 1;
    while 2 * (2 * mr) * vpr + 2 * vpr + 1 <= regs {
        mr *= 2;
    }
    (mr, nr)
}

/// Enumerate all feasible block configurations on `chip` with dimensions
/// up to `max` (step = alignment). Used by the Fig. 6 / Fig. 11 sweeps.
pub fn feasible_blocks(chip: &Chip, max: usize) -> Vec<BlockConfig> {
    let step = chip.align;
    let mut out = Vec::new();
    let mut bm = step;
    while bm <= max {
        let mut bk = step;
        while bk <= max {
            let mut bn = step;
            while bn <= max {
                let cfg = BlockConfig::new(bm, bk, bn);
                if cfg.validate(chip).is_ok() {
                    out.push(cfg);
                }
                bn += step;
            }
            bk += step;
        }
        bm += step;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_best_config_matches_published_nfused() {
        // Paper Sec. 6.3: (b_m, b_k, b_n, N_fused) = (176, 64, 176, 44).
        let chip = Chip::ascend_910a();
        let cfg = BlockConfig::paper_best();
        assert!(cfg.validate(&chip).is_ok());
        assert_eq!(cfg.n_fused(&chip), 44);
        let f = cfg.fusion_factor(&chip);
        assert!((0.92..=1.0).contains(&f), "f={f}");
    }

    #[test]
    fn optimal_bm_matches_paper_range() {
        // Paper: 86 < b_m,opt < 90, rounded to 96.
        let chip = Chip::ascend_910a();
        let opt = optimal_bm(&chip);
        assert!((86.0..90.0).contains(&opt), "opt={opt}");
        assert_eq!(round_to_align(opt, &chip), 96);
    }

    #[test]
    fn constraint_violations_detected() {
        let chip = Chip::ascend_910a();
        assert!(matches!(
            BlockConfig::new(17, 64, 64).validate(&chip),
            Err(ConstraintViolation::Alignment { .. })
        ));
        assert!(matches!(
            BlockConfig::new(256, 128, 16).validate(&chip),
            Err(ConstraintViolation::L0aCapacity { .. })
        ));
        assert!(matches!(
            BlockConfig::new(16, 128, 256).validate(&chip),
            Err(ConstraintViolation::L0bCapacity { .. })
        ));
        assert!(matches!(
            BlockConfig::new(224, 16, 224).validate(&chip),
            Err(ConstraintViolation::UbCapacity { .. })
        ));
        // (176, 64, 176) passes all of Eq. 12 (checked above).
    }

    #[test]
    fn nfused_decreases_with_block_area() {
        let chip = Chip::ascend_910a();
        let small = BlockConfig::new(64, 64, 64).n_fused(&chip);
        let large = BlockConfig::new(176, 64, 176).n_fused(&chip);
        assert!(small > large);
    }

    #[test]
    fn traffic_model_terms() {
        let chip = Chip::ascend_910a();
        let shape = GemmShape::new(4096, 4096, 4096);
        let cfg = BlockConfig::paper_best();
        let t = Traffic::of(shape, cfg, &chip);
        assert_eq!(t.a_read, 4096.0 * 4096.0);
        // B reloads = mkn / (N_core * bm).
        let expect_b = 4096f64.powi(3) / (32.0 * 176.0);
        assert!((t.b_read - expect_b).abs() / expect_b < 1e-12);
        assert!(t.c_rw > 0.0);
        assert!(t.total_elems() > t.a_read + t.b_read);
        assert!(t.total_bytes(4.0, 4.0, 4.0) > 4.0 * t.total_elems() - 1.0);
    }

    #[test]
    fn larger_bm_cuts_b_traffic_raises_c_traffic() {
        let chip = Chip::ascend_910a();
        let shape = GemmShape::new(4096, 4096, 4096);
        let small = Traffic::of(shape, BlockConfig::new(96, 64, 96), &chip);
        let large = Traffic::of(shape, BlockConfig::new(176, 64, 176), &chip);
        assert!(large.b_read < small.b_read);
        assert!(large.c_rw > small.c_rw);
    }

    #[test]
    fn host_blocked_traffic_counts_passes() {
        let shape = GemmShape::new(1024, 1024, 1024);
        let t = Traffic::host_blocked(shape, BlockConfig::new(64, 256, 64));
        assert_eq!(t.a_read, 1024.0 * 1024.0 * 16.0); // 16 column blocks
        assert_eq!(t.b_read, 1024.0 * 1024.0); // packed once
        assert_eq!(t.c_rw, 2.0 * 1024.0 * 1024.0 * 4.0); // 4 k blocks
        // Bigger b_k cuts C revisits; bigger b_n cuts A re-reads.
        let wide = Traffic::host_blocked(shape, BlockConfig::new(64, 512, 128));
        assert!(wide.c_rw < t.c_rw);
        assert!(wide.a_read < t.a_read);
    }

    #[test]
    fn constraint_violation_messages_render() {
        let chip = Chip::ascend_910a();
        let err = BlockConfig::new(17, 64, 64).validate(&chip).unwrap_err();
        assert!(format!("{err}").contains("multiples of 16"));
        let err = BlockConfig::new(256, 128, 16).validate(&chip).unwrap_err();
        assert!(format!("{err}").contains("L0A"));
    }

    #[test]
    fn micro_tile_matches_pack_geometry_on_every_register_file() {
        // AVX2: 16 YMM × 8 lanes; NEON: 32 q × 4 lanes. Both derive the
        // narrow 4×8 tile the pack layer pins as MR/NR.
        assert_eq!(micro_tile(16, 8), (4, 8));
        assert_eq!(micro_tile(32, 4), (4, 8));
        let (mr, nr) = micro_tile(16, 8);
        assert_eq!((mr, nr), (crate::gemm::pack::MR, crate::gemm::pack::NR));
        // AVX-512: 32 zmm × 16 lanes derives the wide 8×16 tile pinned
        // as MAX_MR/MAX_NR and carried by Lane::tile_dims.
        assert_eq!(micro_tile(32, 16), (8, 16));
        assert_eq!(
            micro_tile(32, 16),
            (crate::gemm::pack::MAX_MR, crate::gemm::pack::MAX_NR)
        );
        assert_eq!(
            crate::gemm::kernels::Lane::Avx512.tile_dims(),
            (crate::gemm::pack::MAX_MR, crate::gemm::pack::MAX_NR)
        );
    }

    #[test]
    fn micro_tile_scales_with_register_budget() {
        // NR is lane-granular: a 16-lane file still rounds the row to
        // whole vectors; a 4-lane row needs two vectors.
        assert_eq!(micro_tile(32, 16).1, 16);
        assert_eq!(micro_tile(32, 4).1, 8);
        // MR grows with the register file and shrinks with starvation.
        assert!(micro_tile(64, 8).0 > micro_tile(16, 8).0);
        assert_eq!(micro_tile(8, 8).0, 2); // 2·2·1 + 2 + 1 = 7 regs
        assert_eq!(micro_tile(6, 8).0, 1);
    }

    #[test]
    fn feasible_blocks_nonempty_and_valid() {
        let chip = Chip::ascend_910a();
        let blocks = feasible_blocks(&chip, 256);
        assert!(blocks.len() > 100);
        assert!(blocks.iter().all(|b| b.validate(&chip).is_ok()));
        assert!(blocks.contains(&BlockConfig::paper_best()));
    }
}
