//! Offline stub of the `xla` PJRT bindings.
//!
//! The real runtime links libxla through a vendored crate closure that is
//! only present on hosts with a PJRT toolchain. This stub carries the
//! exact API surface `sgemm_cube::runtime` compiles against, so the
//! `pjrt` feature can be *built* anywhere:
//!
//! * [`Literal`] is functional for host-side f32 data (construction,
//!   reshape, dtype tagging, readback) — enough for the literal
//!   conversion layer and its tests.
//! * Everything that would touch an actual PJRT client
//!   ([`PjRtClient::cpu`], compilation, execution, HLO parsing) returns
//!   a descriptive error.
//!
//! To run artifacts for real, point the workspace `xla` path dependency
//! at the vendored PJRT crate instead of this stub.

use std::borrow::Borrow;
use std::fmt;

/// Stub error: always "PJRT unavailable" for execution paths.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT is unavailable in this build (the `xla` dependency is the offline \
         stub; vendor the real PJRT crate to execute artifacts)"
    ))
}

/// Element types the artifacts use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F16,
    F32,
}

/// Conversion between host scalars and literal storage (f32-backed).
pub trait NativeType: Copy {
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
}

/// Host-side tensor value. The stub stores data as f32 regardless of the
/// tagged dtype; conversion is a tag change (exact for the f16-widened
/// round trips the runtime performs).
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
    ty: PrimitiveType,
}

impl Literal {
    /// Rank-1 literal over an f32 slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64], ty: PrimitiveType::F32 }
    }

    /// Reshape; the element count must be preserved.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: cannot view {} elements as {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec(), ty: self.ty })
    }

    /// Convert the element type (tag-only in the stub).
    pub fn convert(&self, ty: PrimitiveType) -> Result<Literal> {
        Ok(Literal { data: self.data.clone(), dims: self.dims.clone(), ty })
    }

    /// Read the data back as host scalars.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Decompose a tuple literal — only execution produces tuples, so the
    /// stub has none.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> PrimitiveType {
        self.ty
    }
}

/// PJRT client handle (never constructible in the stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("HloModuleProto::from_text_file({path:?})")))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let m = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(m.dims(), &[2, 2]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.reshape(&[3, 3]).is_err());
        let h = m.convert(PrimitiveType::F16).unwrap();
        assert_eq!(h.ty(), PrimitiveType::F16);
    }

    #[test]
    fn execution_paths_report_stub() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err}").contains("PJRT is unavailable"));
        let err = HloModuleProto::from_text_file("x.hlo.txt").err().unwrap();
        assert!(format!("{err}").contains("x.hlo.txt"));
    }
}
