//! Minimal, dependency-free reimplementation of the `anyhow` error API.
//!
//! The offline build image has no crates.io registry, so this vendored
//! crate provides exactly the surface the workspace uses — `Error`,
//! `Result`, the `anyhow!`/`bail!` macros and the `Context` extension
//! trait — with the same semantics as the real crate for those paths:
//!
//! * `Display` shows the outermost message; `{:#}` joins the whole
//!   context chain with `": "`.
//! * `Debug` shows the message plus a `Caused by:` list, like anyhow's
//!   report format.
//! * `?` converts any `std::error::Error + Send + Sync + 'static` via a
//!   blanket `From` (which is why `Error` itself deliberately does *not*
//!   implement `std::error::Error` — the same trade the real crate makes).
//!
//! Errors are captured as message chains (outermost context first); no
//! backtraces and no downcasting, which nothing in this workspace needs.

use std::convert::Infallible;
use std::error::Error as StdError;
use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error: a chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M>(message: M) -> Error
    where
        M: Display + Send + Sync + 'static,
    {
        Error { chain: vec![message.to_string()] }
    }

    fn from_std<E: StdError>(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C>(mut self, context: C) -> Error
    where
        C: Display + Send + Sync + 'static,
    {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The message chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::from_std(e)
    }
}

/// Construct an [`Error`] from format arguments (or a displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Ensure a condition holds, or return an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

mod ext {
    use super::*;

    // Mirrors anyhow's internal extension trait: implemented for every
    // std error *and* for `Error` itself, so `Context` works on both
    // `Result<T, E: std::error::Error>` and `Result<T, anyhow::Error>`.
    // The two impls do not overlap because `Error` does not implement
    // `std::error::Error`.
    pub trait StdErrorExt {
        fn into_error(self) -> Error;
    }

    impl<E> StdErrorExt for E
    where
        E: StdError + Send + Sync + 'static,
    {
        fn into_error(self) -> Error {
            Error::from_std(self)
        }
    }

    impl StdErrorExt for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Extension trait attaching context to `Result` and `Option`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    /// Wrap the error value with context computed lazily on failure.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::StdErrorExt,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(ext::StdErrorExt::into_error(e).context(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(ext::StdErrorExt::into_error(e).context(f())),
        }
    }
}

impl<T> Context<T, Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context.to_string())),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f().to_string())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug)]
    struct Leaf;
    impl Display for Leaf {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str("leaf failed")
        }
    }
    impl StdError for Leaf {}

    #[derive(Debug)]
    struct Mid(Leaf);
    impl Display for Mid {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str("mid failed")
        }
    }
    impl StdError for Mid {
        fn source(&self) -> Option<&(dyn StdError + 'static)> {
            Some(&self.0)
        }
    }

    #[test]
    fn display_shows_outermost_and_alternate_joins_chain() {
        let e: Error = Err::<(), _>(Leaf).context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: leaf failed");
    }

    #[test]
    fn source_chain_is_captured() {
        let e: Error = Err::<(), _>(Mid(Leaf)).context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: mid failed: leaf failed");
        assert_eq!(e.root_cause(), "leaf failed");
    }

    #[test]
    fn debug_reports_causes() {
        let e: Error = Err::<(), _>(Mid(Leaf)).with_context(|| "outer").unwrap_err();
        let d = format!("{e:?}");
        assert!(d.contains("outer"));
        assert!(d.contains("Caused by"));
        assert!(d.contains("leaf failed"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn macros_build_errors() {
        fn f(flag: bool) -> Result<()> {
            if flag {
                bail!("flag was {flag}");
            }
            Ok(())
        }
        assert_eq!(format!("{}", f(true).unwrap_err()), "flag was true");
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
        let m = Error::msg("plain".to_string());
        assert_eq!(format!("{m}"), "plain");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let e = none.context("nothing there").unwrap_err();
        assert_eq!(format!("{e}"), "nothing there");
        assert_eq!(Some(3u8).context("unused").unwrap(), 3);
    }

    #[test]
    fn context_on_anyhow_result_layers() {
        let e: Error = Err::<(), _>(anyhow!("inner"))
            .context("middle")
            .context("outer")
            .unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: middle: inner");
        assert_eq!(e.root_cause(), "inner");
        assert_eq!(e.chain().count(), 3);
    }
}
