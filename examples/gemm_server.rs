//! GEMM serving demo: batched requests through the L3 coordinator.
//!
//! ```bash
//! cargo run --release --example gemm_server
//! ```
//!
//! Starts the GEMM service (shape- and weight-keyed dynamic batching +
//! range-aware precision policy), drives it with a mixed workload from
//! several client threads — moderate-range requests (routed to
//! SGEMM-cube), loose-budget requests (FP16) and out-of-range requests
//! (FP32 fallback) — then runs a serving phase against registered
//! weights (batched per weight, executed from prepacked panels) and
//! prints the latency/throughput report plus the prepack-cache counters.

use std::time::Duration;

use sgemm_cube::coordinator::batcher::BatcherConfig;
use sgemm_cube::coordinator::policy::PrecisionPolicy;
use sgemm_cube::coordinator::server::{GemmService, ServiceConfig};
use sgemm_cube::gemm::backend::Backend;
use sgemm_cube::util::mat::Matrix;
use sgemm_cube::util::rng::Rng;

fn main() {
    let cfg = ServiceConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) },
        policy: PrecisionPolicy::default(),
        n_workers: 0, // auto
        ..Default::default()
    };
    let svc = GemmService::start(cfg);

    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 32;

    std::thread::scope(|scope| {
        for client in 0..CLIENTS {
            let svc = &svc;
            scope.spawn(move || {
                let mut rng = Rng::new(100 + client as u64);
                let mut routed = [0usize; 3];
                for i in 0..PER_CLIENT {
                    // Mixed workload: mostly moderate-range, some huge
                    // (forces the FP32 fallback), some explicit-fp16.
                    let (e, backend) = match i % 8 {
                        7 => (18, None),                    // out of FP16 range
                        5 => (0, Some(Backend::Fp16)),      // caller-pinned
                        _ => (client as i32 - 2, None),     // policy decides
                    };
                    let m = 64 + 32 * (i % 3);
                    let a = Matrix::random_symmetric(m, m, e, &mut rng);
                    let b = Matrix::random_symmetric(m, m, e, &mut rng);
                    let resp = svc.gemm_blocking(a, b, backend).expect("submit failed");
                    assert!(resp.result.is_ok(), "request failed");
                    match resp.backend {
                        Backend::Fp32 => routed[0] += 1,
                        Backend::Fp16 => routed[1] += 1,
                        _ => routed[2] += 1,
                    }
                }
                println!(
                    "client {client}: fp32-fallback={} fp16={} cube={}",
                    routed[0], routed[1], routed[2]
                );
            });
        }
    });

    // Serving phase: two registered weights, several clients issuing
    // small-m activation batches against them. The batcher groups by
    // weight; the first request per weight packs, the rest hit cache.
    let mut rng = Rng::new(7);
    let kn = 192;
    let weights: Vec<_> = (0..2)
        .map(|_| svc.register_weights(Matrix::random_symmetric(kn, kn, 0, &mut rng)))
        .collect();
    std::thread::scope(|scope| {
        for client in 0..CLIENTS {
            let svc = &svc;
            let weights = &weights;
            scope.spawn(move || {
                let mut rng = Rng::new(200 + client as u64);
                for i in 0..PER_CLIENT {
                    let a = Matrix::random_symmetric(8, kn, 0, &mut rng);
                    let resp = svc
                        .gemm_blocking_prepacked(a, weights[i % weights.len()], None)
                        .expect("submit failed");
                    assert!(resp.result.is_ok(), "prepacked request failed");
                }
            });
        }
    });
    let s = svc.prepack_stats();
    println!(
        "\nprepack cache: hits={} misses={} entries={} bytes={}  (hit rate {:.0}%)",
        s.hits,
        s.misses,
        s.entries,
        s.bytes,
        s.hit_rate() * 100.0
    );

    println!("\nservice report: {}", svc.metrics().report().line());
    svc.shutdown();
}
