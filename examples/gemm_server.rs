//! GEMM serving demo: batched requests through the L3 coordinator.
//!
//! ```bash
//! cargo run --release --example gemm_server
//! ```
//!
//! Starts the GEMM service (shape-keyed dynamic batching + range-aware
//! precision policy), drives it with a mixed workload from several client
//! threads — moderate-range requests (routed to SGEMM-cube), loose-budget
//! requests (FP16) and out-of-range requests (FP32 fallback) — and prints
//! the latency/throughput report.

use std::time::Duration;

use sgemm_cube::coordinator::batcher::BatcherConfig;
use sgemm_cube::coordinator::policy::PrecisionPolicy;
use sgemm_cube::coordinator::server::{GemmService, ServiceConfig};
use sgemm_cube::gemm::backend::Backend;
use sgemm_cube::util::mat::Matrix;
use sgemm_cube::util::rng::Rng;

fn main() {
    let cfg = ServiceConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) },
        policy: PrecisionPolicy::default(),
        n_workers: 0, // auto
    };
    let svc = GemmService::start(cfg);

    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 32;

    std::thread::scope(|scope| {
        for client in 0..CLIENTS {
            let svc = &svc;
            scope.spawn(move || {
                let mut rng = Rng::new(100 + client as u64);
                let mut routed = [0usize; 3];
                for i in 0..PER_CLIENT {
                    // Mixed workload: mostly moderate-range, some huge
                    // (forces the FP32 fallback), some explicit-fp16.
                    let (e, backend) = match i % 8 {
                        7 => (18, None),                    // out of FP16 range
                        5 => (0, Some(Backend::Fp16)),      // caller-pinned
                        _ => (client as i32 - 2, None),     // policy decides
                    };
                    let m = 64 + 32 * (i % 3);
                    let a = Matrix::random_symmetric(m, m, e, &mut rng);
                    let b = Matrix::random_symmetric(m, m, e, &mut rng);
                    let resp = svc.gemm_blocking(a, b, backend);
                    assert!(resp.result.is_ok(), "request failed");
                    match resp.backend {
                        Backend::Fp32 => routed[0] += 1,
                        Backend::Fp16 => routed[1] += 1,
                        _ => routed[2] += 1,
                    }
                }
                println!(
                    "client {client}: fp32-fallback={} fp16={} cube={}",
                    routed[0], routed[1], routed[2]
                );
            });
        }
    });

    println!("\nservice report: {}", svc.metrics().report().line());
    svc.shutdown();
}
