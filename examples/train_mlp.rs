//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! ```bash
//! cargo run --release --example train_mlp [-- --steps 300]
//! ```
//!
//! Trains two models with three GEMM backends — FP32, FP16 and
//! SGEMM-cube (termwise) — from identical initializations, logging the
//! loss curves:
//!
//! 1. a noiseless linear-teacher regression driven to machine precision,
//!    where the backend's GEMM error becomes the loss floor (fp16 stalls
//!    ~7 orders of magnitude above fp32; cube stays at fp32's floor);
//! 2. a two-spiral MLP classifier (training accuracy parity check).
//!
//! This is the paper's deep-learning motivation made concrete: the cube
//! backend must track FP32 while pure FP16 visibly degrades.

use sgemm_cube::gemm::backend::{Backend, GemmBackend};
use sgemm_cube::train::{spiral_dataset, teacher_dataset, Mlp};
use sgemm_cube::util::mat::Matrix;
use sgemm_cube::util::rng::Rng;

fn parse_steps() -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(300)
}

fn main() {
    let steps = parse_steps();
    let backends = [Backend::Fp32, Backend::Fp16, Backend::CubeTermwise];

    // ---------------- Regression: random linear teacher ----------------
    // A noiseless linear-teacher problem that gradient descent drives to
    // machine precision. Here the precision floor of the GEMM backend is
    // the floor of the loss itself: FP16's ~2^-11 forward error stops the
    // descent orders of magnitude early, while SGEMM-cube — three FP16
    // Cube passes with precision recovery — keeps descending alongside
    // FP32. This is the paper's Fig. 8 gap (1e-4 vs 1e-7 GEMM error)
    // expressed as an end-to-end loss curve.
    println!("=== regression (noiseless linear teacher, full convergence), {steps} steps ===");
    let mut data_rng = Rng::new(42);
    let (x, y) = teacher_dataset(256, 64, 16, 0.0, &mut data_rng);

    let mut curves: Vec<(Backend, Vec<(usize, f64)>)> = Vec::new();
    for backend in backends {
        let mut init_rng = Rng::new(7); // identical init across backends
        let mut mlp = Mlp::new(&[64, 16], GemmBackend::new(backend), &mut init_rng);
        if curves.is_empty() {
            println!("model: {} parameters (linear), MSE\n", mlp.n_params());
        }
        let log = mlp.train(&x, &y, steps, 5.0, (steps / 15).max(1));
        curves.push((backend, log.iter().map(|r| (r.step, r.loss)).collect()));
    }

    println!("{:>6} {:>14} {:>14} {:>14}", "step", "fp32", "fp16", "cube-termwise");
    for i in 0..curves[0].1.len() {
        let step = curves[0].1[i].0;
        println!(
            "{:>6} {:>14.4e} {:>14.4e} {:>14.4e}",
            step, curves[0].1[i].1, curves[1].1[i].1, curves[2].1[i].1
        );
    }
    let final_losses: Vec<f64> = curves.iter().map(|c| c.1.last().unwrap().1).collect();
    let cube_vs_fp32 = final_losses[2] / final_losses[0];
    let fp16_vs_fp32 = final_losses[1] / final_losses[0];
    println!("\nfinal loss ratio vs fp32: cube {cube_vs_fp32:.2}x, fp16 {fp16_vs_fp32:.1}x");

    // ---------------- Classification: two spirals -----------------------
    println!("\n=== classification (two spirals), {steps} steps ===");
    let mut srng = Rng::new(9);
    let (sx, sy) = spiral_dataset(200, 8, &mut srng);
    for backend in backends {
        let mut init_rng = Rng::new(11);
        let mut mlp = Mlp::new(&[8, 64, 64, 2], GemmBackend::new(backend), &mut init_rng);
        mlp.train(&sx, &sy, steps * 4, 0.3, steps * 4);
        let acc = accuracy(&mlp, &sx, &sy);
        println!("  {:<16} train accuracy = {:.1}%", backend.name(), acc * 100.0);
    }

    println!("\nSuccess criterion: cube-termwise tracks fp32 (≈ equal losses/accuracy);");
    println!("fp16's 11-bit mantissa shows as a visibly worse regression loss.");
}

fn accuracy(mlp: &Mlp, x: &Matrix<f32>, y: &Matrix<f32>) -> f64 {
    let pred = mlp.predict(x);
    let mut correct = 0;
    for i in 0..x.rows() {
        let p = if pred.get(i, 0) >= pred.get(i, 1) { 0 } else { 1 };
        let t = if y.get(i, 0) == 1.0 { 0 } else { 1 };
        if p == t {
            correct += 1;
        }
    }
    correct as f64 / x.rows() as f64
}
