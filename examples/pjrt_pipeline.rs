//! Full three-layer pipeline: AOT Pallas artifacts driven from rust.
//!
//! ```bash
//! make artifacts && cargo run --release --example pjrt_pipeline
//! ```
//!
//! Demonstrates every shipped artifact through the PJRT runtime:
//! the split kernel, HGEMM and SGEMM-cube GEMMs at several shapes, the
//! AOT MLP forward pass, and a short training loop using the AOT
//! `mlp_train_step` artifact (loss + updated parameters computed wholly
//! inside the compiled XLA program — Python is not involved at runtime).

use anyhow::Result;
use sgemm_cube::gemm::dgemm::dgemm_of_f32;
use sgemm_cube::gemm::error::relative_error;
use sgemm_cube::runtime::Engine;
use sgemm_cube::softfloat::split::{SplitConfig, SplitMatrix};
use sgemm_cube::util::mat::Matrix;
use sgemm_cube::util::rng::Rng;

fn main() -> Result<()> {
    let engine = Engine::from_default_dir()?;
    println!("PJRT platform: {}", engine.platform());
    println!("artifacts: {:?}\n", engine.manifest().names());

    let mut rng = Rng::new(1);

    // --- split kernel vs the rust softfloat substrate -------------------
    let x = Matrix::random_symmetric(128, 128, 0, &mut rng);
    let out = engine.run("split_128", &[&x])?;
    let native = SplitMatrix::from_f32(&x, SplitConfig::default());
    // The artifact returns fp16 widened to f32 by the runtime conversion.
    let mut max_diff = 0.0f32;
    for i in 0..128 {
        for j in 0..128 {
            let d = (out[0].get(i, j) - native.high.get(i, j).to_f32()).abs();
            max_diff = max_diff.max(d);
        }
    }
    println!("split_128: AOT high-part vs rust softfloat, max |diff| = {max_diff}");

    // --- GEMM artifacts at several shapes --------------------------------
    for (name, m, k, n) in [
        ("cube_gemm_64", 64, 64, 64),
        ("cube_gemm_128", 128, 128, 128),
        ("cube_gemm_256", 256, 256, 256),
        ("cube_gemm_128x256x128", 128, 256, 128),
        ("hgemm_128", 128, 128, 128),
    ] {
        let a = Matrix::random_symmetric(m, k, 0, &mut rng);
        let b = Matrix::random_symmetric(k, n, 0, &mut rng);
        let t0 = std::time::Instant::now();
        let c = engine.gemm(name, &a, &b)?;
        let dt = t0.elapsed().as_secs_f64();
        let err = relative_error(&dgemm_of_f32(&a, &b), &c.to_f64());
        println!("{name:<22} err={err:.3e}  exec={:.2}ms", dt * 1e3);
    }

    // --- AOT MLP forward + training steps --------------------------------
    println!("\nAOT MLP (64→128→128→32), training via the mlp_train_step artifact:");
    let sizes = [64usize, 128, 128, 32];
    let batch = 64;
    let mut params: Vec<Matrix<f32>> = Vec::new();
    for w in sizes.windows(2) {
        let std = (2.0 / w[0] as f32).sqrt();
        params.push(Matrix::random_normal(w[0], w[1], std, &mut rng));
        params.push(Matrix::zeros(1, w[1])); // bias as row vector
    }
    let x = Matrix::random_normal(batch, sizes[0], 1.0, &mut rng);
    let teacher = Matrix::random_normal(sizes[0], sizes[3], 0.3, &mut rng);
    let y = sgemm_cube::gemm::sgemm::sgemm(&x, &teacher);

    for step in 0..10 {
        let mut inputs: Vec<&Matrix<f32>> = vec![&x, &y];
        inputs.extend(params.iter());
        let out = engine.run("mlp_train_step", &inputs)?;
        let loss = out[0].get(0, 0);
        if step % 3 == 0 || step == 9 {
            println!("  step {step}: loss = {loss:.6}");
        }
        params = out[1..].to_vec();
    }
    println!("\n(the entire fwd+bwd+SGD step ran inside the AOT XLA program)");
    Ok(())
}
