//! Quickstart: one precision-recovery GEMM three ways.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Computes `C = A·B` (128³, entries in U[-1, 1]) with (1) the native
//! SGEMM-cube numerics engine, (2) plain FP16 and FP32 baselines, and —
//! if `make artifacts` has been run — (3) the AOT-compiled Pallas kernel
//! through the PJRT runtime. Reports the Eq. (13) relative error of each
//! against the FP64 reference. Then demonstrates the serving flow:
//! register a weight matrix once, serve repeated small-batch requests
//! against its prepacked panels, and show the cache doing the work.

use sgemm_cube::coordinator::server::{GemmService, ServiceConfig};
use sgemm_cube::gemm::backend::{Backend, GemmBackend};
use sgemm_cube::gemm::blocked::cube_gemm_blocked;
use sgemm_cube::gemm::dgemm::dgemm_of_f32;
use sgemm_cube::gemm::error::relative_error;
use sgemm_cube::softfloat::split::SplitConfig;
use sgemm_cube::util::mat::Matrix;
use sgemm_cube::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(42);
    let n = 128;
    let a = Matrix::random_symmetric(n, n, 0, &mut rng);
    let b = Matrix::random_symmetric(n, n, 0, &mut rng);
    let c_ref = dgemm_of_f32(&a, &b);
    let err = |c: &Matrix<f32>| relative_error(&c_ref, &c.to_f64());

    println!("C = A·B at {n}³, entries U[-1, 1]; errors vs FP64 (Eq. 13):\n");
    for backend in Backend::ALL {
        let c = GemmBackend::new(backend).gemm(&a, &b);
        println!("  {:<18} err = {:.3e}", backend.name(), err(&c));
    }

    pjrt_demo(&a, &b, &c_ref);

    println!("\nExpected ordering: fp16 ≈ 1e-4  >>  cube ≈ fp32 ≈ 1e-7.");

    serving_demo(&mut rng);
    Ok(())
}

/// Register-weights-then-serve: the weight's FP32→2×FP16 split and panel
/// packing happen once, on the first request; every later request only
/// prepares its (tiny) activation operand. Results are bit-identical to
/// the one-shot blocked path.
fn serving_demo(rng: &mut Rng) {
    let (m, kn) = (8, 256);
    println!("\n== serving: register weights once, then {m}×{kn} activations ==");
    let w = Matrix::random_symmetric(kn, kn, 0, rng);
    let svc = GemmService::start(ServiceConfig::default());
    let weights = svc.register_weights(w.clone());
    for step in 0..4 {
        let a = Matrix::random_symmetric(m, kn, 0, rng);
        let resp = svc.gemm_blocking_prepacked(a.clone(), weights, None).expect("submit failed");
        let c = resp.result.expect("serving failed");
        let one_shot = cube_gemm_blocked(&a, &w, SplitConfig::with_scale(resp.scale_exp));
        let bit_identical = c
            .as_slice()
            .iter()
            .zip(one_shot.as_slice())
            .all(|(x, y)| x.to_bits() == y.to_bits());
        println!(
            "  step {step}: backend={} s_b={} bit-identical-to-blocked={bit_identical}",
            resp.backend, resp.scale_exp
        );
        assert!(bit_identical);
    }
    let s = svc.prepack_stats();
    println!(
        "  prepack cache: {} hit(s), {} miss(es), {} entr{} ({} KiB) — pack cost paid once",
        s.hits,
        s.misses,
        s.entries,
        if s.entries == 1 { "y" } else { "ies" },
        s.bytes / 1024
    );
    svc.shutdown();
}

#[cfg(feature = "pjrt")]
fn pjrt_demo(a: &Matrix<f32>, b: &Matrix<f32>, c_ref: &Matrix<f64>) {
    use sgemm_cube::runtime::Engine;
    match Engine::from_default_dir() {
        Ok(engine) => match engine.gemm("cube_gemm_128", a, b) {
            Ok(c) => println!(
                "  {:<18} err = {:.3e}  (Pallas kernel via PJRT)",
                "aot-cube",
                relative_error(c_ref, &c.to_f64())
            ),
            Err(e) => println!("\n(PJRT execution failed: {e})"),
        },
        Err(e) => {
            println!("\n(skipping PJRT path: {e}; run `make artifacts`)");
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_demo(_a: &Matrix<f32>, _b: &Matrix<f32>, _c_ref: &Matrix<f64>) {
    println!("\n(PJRT path disabled at build time; rebuild with --features pjrt)");
}
