//! Accuracy study: the paper's Sec. 6.2 experiments at user scale.
//!
//! ```bash
//! cargo run --release --example accuracy_study [-- --full]
//! ```
//!
//! Sweeps the FP32 offset exponent (Fig. 8) and the accumulation depth k
//! (Fig. 9) and prints relative-error tables for HGEMM, FP32 SGEMM and
//! SGEMM-cube under both accumulation orders and s_b ∈ {0, 6, 12}.

use sgemm_cube::experiments::{fig8_accuracy, fig9_size_accuracy};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let (n, seeds) = if full { (128, 5) } else { (64, 2) };

    println!("SGEMM-cube accuracy study (n = {n}, {seeds} seeds per point)\n");

    let exps: Vec<i32> = (-14..=12).step_by(2).collect();
    fig8_accuracy::run(fig8_accuracy::Sampling::Symmetric, n, &exps, seeds).emit(None);
    fig8_accuracy::run(fig8_accuracy::Sampling::NonNegative, n, &exps, seeds).emit(None);

    fig9_size_accuracy::run_mn_sweep(&[32, 64, 128], 512, seeds).emit(None);
    fig9_size_accuracy::run_k_sweep(32, &[128, 512, 2048, 8192], seeds).emit(None);

    println!("Reading guide (matches the paper):");
    println!("  * hgemm sits at ~1e-4 everywhere — the 11-bit floor.");
    println!("  * cube s_b=12 tracks (or beats) fp32 SGEMM for e ≥ -12.");
    println!("  * without scaling (s_b=0) the cube collapses for e ≤ -10 (Rule 1).");
    println!("  * termwise ≤ elementwise as k grows (stable small-sum aggregation).");
}
